//! TCP optimization service: the long-running "request path" deployment.
//!
//! JSON requests over TCP, framed by a per-connection codec (newline-
//! delimited by default, length-prefixed binary by negotiation — see
//! [`crate::coordinator::codec`]). The server loads the offline dataset
//! and the PJRT artifacts once at startup; each request runs one
//! optimization and returns the recommended deployment. Python is never
//! involved.
//!
//! Request:
//!   {"op": "optimize", "workload": "kmeans:santander", "target": "cost",
//!    "method": "cb-rbfopt", "budget": 33, "seed": 1,
//!    "trial_workers": 3, "measure_mode": "single_draw",
//!    "include_trace": false}
//!   {"op": "batch", "requests": [{...}, {...}, ...]}
//!   {"op": "list_workloads"}
//!   {"op": "list_methods"}
//!   {"op": "stats"}
//!   {"op": "clear_cache"}
//!   {"op": "ping"}
//!
//! ## Serving architecture
//!
//! All requests flow through one shared [`Scheduler`]:
//!
//! * **One worker team per process.** Compute parallelism (bandit arm
//!   fan-out, batch fan-out) runs on the persistent
//!   [`global_team`](crate::util::threadpool::global_team) — no thread is
//!   spawned per request or per bandit sweep.
//! * **Sharded readiness-driven connections (default on Unix).** One
//!   acceptor thread owns the listener and distributes accepted sockets
//!   round-robin-by-load to [`Service::with_reactors`] reactor threads
//!   (default `min(cores, 4)`). Each reactor owns its own
//!   [`Readiness`](crate::util::net::Readiness) instance, wake pipe,
//!   outbox, and a disjoint subset of connections: it does nonblocking
//!   framed reads into per-connection buffers, hands only *complete*
//!   request lines to the shared connection-worker pool
//!   ([`Service::with_conn_workers`]), and writes responses back
//!   nonblockingly. Idle keep-alive connections therefore cost one fd
//!   each — never a pinned worker — so `64` idle clients on a
//!   two-worker pool cannot starve a new arrival. Per connection at
//!   most one request executes at a time and a connection never
//!   migrates between reactors, so pipelined requests are answered
//!   strictly in order, byte-identical to the threaded path at any
//!   reactor count.
//! * **Three transports, one contract.** [`Service::with_transport`]
//!   (CLI `--transport epoll|poll|threaded|auto`) picks the backend:
//!   [`Transport::Epoll`] registers sockets once and pays O(ready
//!   events) per wakeup (Linux default — what holds 100k idle
//!   connections for the price of the active few); [`Transport::Poll`]
//!   drives the same loop over a persistent `poll(2)` set (portable
//!   Unix, O(open) kernel scan per wakeup); [`Transport::Threaded`] is
//!   the classic bounded accept queue + fixed worker pool, kept for
//!   non-Unix platforms and differential testing. All three produce
//!   byte-identical response streams by contract — the suite asserts
//!   it.
//! * **Runtime-tunable limits.** Every serving limit that used to be a
//!   compile-time constant — connection cap, idle reap timeout, write
//!   backpressure, pipelining depth, shutdown drain — is a
//!   [`ServiceLimits`] field with a `Service` builder method and a CLI
//!   flag, and the effective values (after the connection cap is
//!   clamped to `RLIMIT_NOFILE`) are reported by the `stats` op.
//! * **Adaptive arm workers.** A request that leaves `trial_workers`
//!   unset (or 0) gets `max(1, cores / in-flight requests)` arm workers —
//!   a lone request fans its bandit arms across the machine, a busy
//!   server leans on request-level parallelism instead. Explicit values
//!   are honored as before. Either way results are bit-identical; the
//!   knob only moves latency.
//! * **Cross-request response cache (bounded, lock-striped LRU).**
//!   Deterministic-mode requests (`measure_mode` of `mean`/`p90`) are
//!   answered from a cache keyed by (workload, target, method, budget,
//!   seed, measure_mode): a repeat request returns the byte-identical
//!   response with zero new source measurements. Keys hash to one of
//!   [`Service::with_cache_shards`] independent stripes (default
//!   [`DEFAULT_CACHE_SHARDS`]), each with its own lock and LRU order,
//!   so concurrent reactors and workers rarely contend. The cache holds
//!   at most [`Service::with_cache_cap`] entries globally (default
//!   [`DEFAULT_CACHE_CAP`], split across stripes) and evicts
//!   least-recently-used per stripe, so a long-lived server stays
//!   bounded under adversarial key churn; `{"op":"clear_cache"}` drops
//!   it wholesale. `single_draw` requests are never cached (repeat
//!   evaluations legitimately re-draw).
//! * **Batch op.** `{"op":"batch","requests":[...]}` fans a request list
//!   across the team and returns per-request responses in input order;
//!   a failing entry yields an error object in its slot without
//!   poisoning the rest. Identical *deterministic* entries are
//!   pre-grouped so each distinct key runs exactly one trial (the
//!   duplicates receive copies of the representative's response) —
//!   a guarantee, not the cache race it used to be. Entries executed on
//!   team threads run their own arm fan-out inline — request-level
//!   parallelism already saturates the team, so per-entry arm workers
//!   would only add queue pressure.
//!
//! Response (optimize):
//!   {"ok": true, "config": "gcp/family=e2/...", "value": 0.123,
//!    "evals": 33, "search_expense": 4.56, "regret": 0.01}
//!
//! With `"include_trace": true` the response additionally carries
//! `"trace": [best-so-far after each evaluation]` — the convergence
//! curve, stored alongside the cached response so cached hits return it
//! too (even when the cold request didn't ask for it).

use std::collections::{BTreeMap, HashMap};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::codec::{self, Codec, DecodeError, FrameScanner, Greeting};
use crate::coordinator::experiment::{run_online_trial_with, run_trial_with, TrialSpec, PREDICTORS};
use crate::coordinator::spec::{OnlineParams, MAX_DEADLINE_MS, MAX_TRIAL_WORKERS};
use crate::dataset::objective::MeasureMode;
use crate::dataset::{OfflineDataset, Target};
use crate::optimizers::ALL_OPTIMIZERS;
use crate::surrogate::Backend;
use crate::util::cancel::{CancelReason, CancelToken};
use crate::util::json::{parse, Value};
use crate::util::threadpool::{default_workers, global_team, parallel_map_owned, WorkerTeam};

/// Largest request list one batch op accepts.
pub const MAX_BATCH: usize = 256;

/// Largest accepted request frame in bytes — defined once in the codec
/// module and re-exported here for existing users. A connection that
/// exceeds it gets one error response and a close, on every transport
/// and codec.
pub use crate::coordinator::codec::MAX_FRAME;

/// Default bound on cached deterministic-mode responses (LRU beyond it).
pub const DEFAULT_CACHE_CAP: usize = 1024;

/// Cache key for deterministic-mode responses. `trial_workers` is
/// deliberately absent: worker counts never change results, so requests
/// differing only in parallelism share one cache entry.
#[derive(Clone, PartialEq, Eq, Hash)]
struct ResponseKey {
    workload: usize,
    target: Target,
    method: String,
    budget: usize,
    seed: u64,
    mode: MeasureMode,
}

/// What the response cache holds per key: the response body plus the
/// ledger's convergence trace, so a cached hit can honor
/// `include_trace` even when the cold request never asked for it. The
/// body is also stored pre-serialized (`resp_str`), so the common
/// cached hit (no trace requested) is answered by one string clone —
/// no `Value` tree clone, no re-serialization.
#[derive(Clone)]
struct CachedResponse {
    resp: Value,
    resp_str: String,
    trace: Value,
}

/// Bounded LRU store behind the cross-request response cache: a key map
/// carrying each entry's last-use tick plus a tick-ordered index, so a
/// hit is O(log n) and eviction pops the stalest tick. Plain maps (no
/// external LRU crate — this tree builds offline with zero deps).
struct ResponseCache {
    cap: usize,
    tick: u64,
    map: HashMap<ResponseKey, (CachedResponse, u64)>,
    order: BTreeMap<u64, ResponseKey>,
}

impl ResponseCache {
    fn new(cap: usize) -> ResponseCache {
        ResponseCache { cap: cap.max(1), tick: 0, map: HashMap::new(), order: BTreeMap::new() }
    }

    /// Look up and mark as most-recently-used.
    fn get(&mut self, key: &ResponseKey) -> Option<CachedResponse> {
        self.touch(key).map(|entry| entry.clone())
    }

    /// Like [`get`](Self::get), but clone only the pre-serialized
    /// response string — the cached-hit fast path for requests that
    /// want no trace.
    fn get_str(&mut self, key: &ResponseKey) -> Option<String> {
        self.touch(key).map(|entry| entry.resp_str.clone())
    }

    /// Find an entry and refresh its recency.
    fn touch(&mut self, key: &ResponseKey) -> Option<&CachedResponse> {
        self.tick += 1;
        let tick = self.tick;
        let (resp, last) = self.map.get_mut(key)?;
        let stale = std::mem::replace(last, tick);
        self.order.remove(&stale);
        self.order.insert(tick, key.clone());
        Some(resp)
    }

    /// Insert (first writer wins), evicting least-recently-used entries
    /// past the cap. Returns whether the entry was inserted and how many
    /// entries were evicted.
    fn insert(&mut self, key: ResponseKey, resp: CachedResponse) -> (bool, usize) {
        if self.map.contains_key(&key) {
            // A racing duplicate computed the identical response
            // (deterministic mode), so the existing entry serves.
            return (false, 0);
        }
        let mut evicted = 0;
        while self.map.len() >= self.cap {
            let Some((&stalest, _)) = self.order.iter().next() else { break };
            if let Some(victim) = self.order.remove(&stalest) {
                self.map.remove(&victim);
                evicted += 1;
            }
        }
        self.tick += 1;
        self.order.insert(self.tick, key.clone());
        self.map.insert(key, (resp, self.tick));
        (true, evicted)
    }

    fn clear(&mut self) -> usize {
        let n = self.map.len();
        self.map.clear();
        self.order.clear();
        n
    }

    fn len(&self) -> usize {
        self.map.len()
    }
}

/// Default stripe count for the response cache. Eight shards keep the
/// per-shard mutex hold times short enough that four reactors plus the
/// connection-worker pool rarely collide on the same stripe, while each
/// stripe still holds enough entries (cap / shards) for LRU to behave.
pub const DEFAULT_CACHE_SHARDS: usize = 8;

/// One consistent view of the response-cache counters, taken stripe by
/// stripe under each stripe's store lock. Per-stripe views are exact,
/// so the identity `inserts - evictions == resident` holds for any
/// snapshot even while other threads are mutating the cache — the
/// property `stats` reports on and the chaos suite hammers.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub inserts: u64,
    pub evictions: u64,
    /// Entries currently resident across all stripes.
    pub resident: usize,
}

/// One stripe of the lock-striped response cache: an independent
/// [`ResponseCache`] plus its own counters, so concurrent reactors
/// touching different stripes share no lock and no contended cache
/// line. `stats` snapshots the counters across stripes.
struct CacheShard {
    store: Mutex<ResponseCache>,
    hits: AtomicU64,
    misses: AtomicU64,
    inserts: AtomicU64,
    evictions: AtomicU64,
}

impl CacheShard {
    fn new(cap: usize) -> CacheShard {
        CacheShard {
            store: Mutex::new(ResponseCache::new(cap)),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            inserts: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }
}

/// Lock-striped LRU response cache: `ResponseKey`s hash to one of S
/// independent shards, each with its own mutex, LRU order, and
/// counters. The global cap is split across shards (remainder spread
/// one-per-shard from the front), so total residency never exceeds the
/// configured cap; eviction recency is per-shard, which is exact global
/// LRU at one shard and an S-way approximation otherwise.
struct StripedCache {
    /// Global entry cap (what `with_cache_cap` set; per-shard caps sum
    /// to exactly this).
    cap: usize,
    /// Stripe count as requested by the builder; the effective count is
    /// capped by `cap` so every shard keeps a cap of at least one.
    requested_shards: usize,
    shards: Vec<CacheShard>,
}

impl StripedCache {
    fn new(cap: usize, requested_shards: usize) -> StripedCache {
        let cap = cap.max(1);
        let n = requested_shards.max(1).min(cap);
        let (base, extra) = (cap / n, cap % n);
        let shards =
            (0..n).map(|i| CacheShard::new(base + usize::from(i < extra))).collect();
        StripedCache { cap, requested_shards: requested_shards.max(1), shards }
    }

    fn shard(&self, key: &ResponseKey) -> &CacheShard {
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        key.hash(&mut h);
        &self.shards[(h.finish() as usize) % self.shards.len()]
    }

    /// Look up, marking the entry most-recently-used in its shard and
    /// counting a hit or a miss on that shard. Counters bump while the
    /// stripe lock is held, so [`snapshot`](Self::snapshot) (which takes
    /// the same lock) always observes counter totals consistent with
    /// the entries it counts.
    fn lookup(&self, key: &ResponseKey) -> Option<CachedResponse> {
        let shard = self.shard(key);
        let mut store = shard.store.lock().unwrap();
        let hit = store.get(key);
        if hit.is_some() {
            shard.hits.fetch_add(1, Ordering::Relaxed);
        } else {
            shard.misses.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// Pre-serialized fast-path lookup: counts a hit only when it
    /// serves one (the miss is recorded by the [`lookup`](Self::lookup)
    /// the request then falls through to).
    fn lookup_str(&self, key: &ResponseKey) -> Option<String> {
        let shard = self.shard(key);
        let mut store = shard.store.lock().unwrap();
        let hit = store.get_str(key);
        if hit.is_some() {
            shard.hits.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    fn store(&self, key: ResponseKey, resp: CachedResponse) {
        let shard = self.shard(&key);
        let store = &mut *shard.store.lock().unwrap();
        let (inserted, evicted) = store.insert(key, resp);
        if inserted {
            shard.inserts.fetch_add(1, Ordering::Relaxed);
        }
        if evicted > 0 {
            shard.evictions.fetch_add(evicted as u64, Ordering::Relaxed);
        }
    }

    fn sum(&self, field: impl Fn(&CacheShard) -> &AtomicU64) -> u64 {
        self.shards.iter().map(|s| field(s).load(Ordering::Relaxed)).sum()
    }

    /// One consistent view of all cache counters: each stripe is read
    /// under its store lock (the same lock every counter bumps under),
    /// so per-stripe views are exact and their sum preserves the
    /// invariant `inserts - evictions == resident` even while writers
    /// hammer other stripes.
    fn snapshot(&self) -> CacheStats {
        let mut total = CacheStats::default();
        for shard in &self.shards {
            let store = shard.store.lock().unwrap();
            total.resident += store.len();
            total.hits += shard.hits.load(Ordering::Relaxed);
            total.misses += shard.misses.load(Ordering::Relaxed);
            total.inserts += shard.inserts.load(Ordering::Relaxed);
            total.evictions += shard.evictions.load(Ordering::Relaxed);
        }
        total
    }

    fn len(&self) -> usize {
        self.shards.iter().map(|s| s.store.lock().unwrap().len()).sum()
    }

    fn clear(&self) -> usize {
        self.shards.iter().map(|s| s.store.lock().unwrap().clear()).sum()
    }
}

/// Process-wide request scheduler: owns the admission count, the
/// adaptive arm-worker sizing, and the cross-request response cache.
/// One per [`Service`]; all connections and batch entries share it.
pub struct Scheduler {
    /// The process compute team all request parallelism lands on.
    team: &'static WorkerTeam,
    in_flight: AtomicUsize,
    cache: StripedCache,
    trials_run: AtomicU64,
    /// Trials cut short because their client vanished (disconnect
    /// mid-trial, or server shutdown firing the live-connection tokens —
    /// both are "the requester is gone", so they share one counter).
    cancelled_disconnect: AtomicU64,
    /// Trials cut short by a request deadline (`deadline_ms` or the
    /// server's `--default-deadline`).
    cancelled_deadline: AtomicU64,
    /// Budget pulls that cancellation saved: source measurements a
    /// cancelled trial was still entitled to but never performed.
    pulls_saved: AtomicU64,
}

/// RAII in-flight marker for one admitted request.
struct Admission<'a>(&'a Scheduler);

impl Drop for Admission<'_> {
    fn drop(&mut self) {
        self.0.in_flight.fetch_sub(1, Ordering::AcqRel);
    }
}

impl Scheduler {
    fn new(cache_cap: usize, cache_shards: usize) -> Scheduler {
        Scheduler {
            team: global_team(),
            in_flight: AtomicUsize::new(0),
            cache: StripedCache::new(cache_cap, cache_shards),
            trials_run: AtomicU64::new(0),
            cancelled_disconnect: AtomicU64::new(0),
            cancelled_deadline: AtomicU64::new(0),
            pulls_saved: AtomicU64::new(0),
        }
    }

    /// Admit one request; the returned guard keeps it counted in-flight.
    fn admit(&self) -> Admission<'_> {
        self.in_flight.fetch_add(1, Ordering::AcqRel);
        Admission(self)
    }

    /// Requests currently executing (including batch entries).
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Acquire)
    }

    /// Arm workers for a request that left `trial_workers` unset: divide
    /// the machine across the requests currently in flight.
    pub fn effective_arm_workers(&self) -> usize {
        (default_workers() / self.in_flight().max(1)).clamp(1, MAX_TRIAL_WORKERS)
    }

    /// Worker threads in the process compute team.
    pub fn team_threads(&self) -> usize {
        self.team.threads()
    }

    /// Responses served straight from the cross-request cache so far
    /// (summed across stripes).
    pub fn cache_hits(&self) -> u64 {
        self.cache.sum(|s| &s.hits)
    }

    /// Deterministic-mode requests that missed the cache (every one runs
    /// a trial, so `hits + misses` = deterministic requests served).
    pub fn cache_misses(&self) -> u64 {
        self.cache.sum(|s| &s.misses)
    }

    /// Entries actually inserted into the cache (misses minus racing
    /// duplicates whose key was already present at store time).
    pub fn cache_inserts(&self) -> u64 {
        self.cache.sum(|s| &s.inserts)
    }

    /// Entries evicted from the response cache so far (LRU past each
    /// stripe's share of the cap).
    pub fn cache_evictions(&self) -> u64 {
        self.cache.sum(|s| &s.evictions)
    }

    /// Optimization trials actually executed (cache misses + uncacheable).
    pub fn trials_run(&self) -> u64 {
        self.trials_run.load(Ordering::Relaxed)
    }

    /// Trials cut short because their requester went away (client
    /// disconnect or server shutdown).
    pub fn cancelled_disconnect(&self) -> u64 {
        self.cancelled_disconnect.load(Ordering::Relaxed)
    }

    /// Trials cut short by a request deadline.
    pub fn cancelled_deadline(&self) -> u64 {
        self.cancelled_deadline.load(Ordering::Relaxed)
    }

    /// Budget pulls cancellation saved across all cancelled trials.
    pub fn pulls_saved(&self) -> u64 {
        self.pulls_saved.load(Ordering::Relaxed)
    }

    /// Deterministic-mode responses currently cached (all stripes).
    pub fn cached_responses(&self) -> usize {
        self.cache.len()
    }

    /// One consistent snapshot of every cache counter (see
    /// [`CacheStats`]): unlike the individual accessors above — which
    /// sum per-stripe atomics without a lock and can interleave with
    /// writers — a snapshot reads each stripe under its store lock, so
    /// `inserts - evictions == resident` holds exactly.
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.snapshot()
    }

    /// Drop every cached response; returns how many were held.
    pub fn clear_cache(&self) -> usize {
        self.cache.clear()
    }

    /// Stripes in the response cache (effective count, ≤ the cap).
    pub fn cache_shards(&self) -> usize {
        self.cache.shards.len()
    }

    fn cache_lookup(&self, key: &ResponseKey) -> Option<CachedResponse> {
        self.cache.lookup(key)
    }

    /// Pre-serialized fast-path lookup. Counts a hit only when it
    /// serves one; a miss counts nothing here, because the request then
    /// falls through to [`run_optimize_data`](Service::run_optimize_data)
    /// whose own lookup records it — so `hits + misses` still equals
    /// deterministic requests served.
    fn cache_lookup_str(&self, key: &ResponseKey) -> Option<String> {
        self.cache.lookup_str(key)
    }

    fn cache_store(&self, key: ResponseKey, resp: CachedResponse) {
        self.cache.store(key, resp);
    }
}

/// Per-reactor gauges published while a multi-reactor serve is live:
/// one `Arc` per reactor thread, registered in
/// [`NetStats::reactor_gauges`] at startup and read by the `stats` op
/// to report `per_reactor_open` / `per_reactor_wakeups` for skew
/// diagnosis. `open` is also the acceptor's load signal for
/// least-loaded distribution.
struct ReactorGauges {
    /// Connections this reactor currently owns (incremented by the
    /// acceptor at hand-off, decremented by the reactor at close).
    open: AtomicUsize,
    /// Of those, connections with nothing buffered and no request in
    /// flight.
    idle: AtomicUsize,
    /// Readiness waits on this reactor that reported at least one ready
    /// fd.
    wakeups: AtomicU64,
}

impl ReactorGauges {
    fn new() -> ReactorGauges {
        ReactorGauges {
            open: AtomicUsize::new(0),
            idle: AtomicUsize::new(0),
            wakeups: AtomicU64::new(0),
        }
    }
}

/// Transport-level gauges surfaced by the `stats` op. Written by the
/// reactor threads (or the threaded workers, which only track
/// `open_connections`), read by any request handler.
struct NetStats {
    /// Open client connections. Under the event loop: every accepted
    /// socket. Under the threaded fallback: connections a worker is
    /// actively serving (sockets parked in the accept queue are not
    /// counted).
    open_connections: AtomicUsize,
    /// Open connections with nothing buffered and no request in flight
    /// (event loop only: the idle keep-alive herd being held for free).
    idle_connections: AtomicUsize,
    /// Event-loop wait returns that reported at least one ready fd.
    loop_wakeups: AtomicU64,
    /// Total readiness events delivered to the event loop. The scaling
    /// story in one counter: divided by `loop_wakeups` it is the mean
    /// per-wakeup work, which stays proportional to *active* (not open)
    /// connections under the epoll transport.
    ready_events: AtomicU64,
    /// Connections whose codec resolved to JSON lines (counted once per
    /// connection, when its first frame settles negotiation).
    json_connections: AtomicU64,
    /// Connections whose codec resolved to binary (magic byte or hello).
    binary_connections: AtomicU64,
    /// Request frames decoded (or answered with a decode error) under
    /// the JSON-lines codec. Negotiation hellos are not requests.
    json_requests: AtomicU64,
    /// Request frames decoded (or answered with a decode error) under
    /// the binary codec.
    binary_requests: AtomicU64,
    /// Per-reactor gauge blocks, published when a readiness-driven
    /// serve starts and cleared when it drains. Empty while not serving
    /// or under the threaded fallback.
    reactor_gauges: Mutex<Vec<Arc<ReactorGauges>>>,
}

impl NetStats {
    fn new() -> NetStats {
        NetStats {
            open_connections: AtomicUsize::new(0),
            idle_connections: AtomicUsize::new(0),
            loop_wakeups: AtomicU64::new(0),
            ready_events: AtomicU64::new(0),
            json_connections: AtomicU64::new(0),
            binary_connections: AtomicU64::new(0),
            json_requests: AtomicU64::new(0),
            binary_requests: AtomicU64::new(0),
            reactor_gauges: Mutex::new(Vec::new()),
        }
    }

    /// Record a connection whose codec negotiation just settled.
    fn count_conn(&self, codec: &'static dyn Codec) {
        let counter = match codec.name() {
            "binary" => &self.binary_connections,
            _ => &self.json_connections,
        };
        counter.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one request frame served under `codec`.
    fn count_request(&self, codec: &'static dyn Codec) {
        let counter = match codec.name() {
            "binary" => &self.binary_requests,
            _ => &self.json_requests,
        };
        counter.fetch_add(1, Ordering::Relaxed);
    }
}

/// How client sockets are served. All three produce byte-identical
/// response streams; they differ only in what a wakeup costs and where
/// they run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Transport {
    /// Readiness registration via `epoll(7)` (Linux): sockets register
    /// once, each wakeup costs O(ready events) regardless of how many
    /// connections are open. The default where available.
    Epoll,
    /// Readiness via a persistent `poll(2)` set (portable Unix): same
    /// event loop, but every wakeup is an O(open connections) kernel
    /// scan.
    Poll,
    /// Thread-per-connection over a bounded accept queue (everywhere):
    /// concurrency = worker count, idle connections pin workers.
    Threaded,
}

impl Transport {
    /// Short name used by the CLI, `stats` op, and benches.
    pub fn name(self) -> &'static str {
        match self {
            Transport::Epoll => "epoll",
            Transport::Poll => "poll",
            Transport::Threaded => "threaded",
        }
    }

    /// The best transport this platform supports: epoll on Linux, poll
    /// on other Unixes, threaded elsewhere.
    pub fn best() -> Transport {
        if crate::util::net::epoll_supported() {
            Transport::Epoll
        } else if crate::util::net::supported() {
            Transport::Poll
        } else {
            Transport::Threaded
        }
    }

    /// Degrade an unavailable choice to the nearest supported transport
    /// (epoll → poll off Linux, poll → threaded off Unix).
    fn available(self) -> Transport {
        match self {
            Transport::Epoll if !crate::util::net::epoll_supported() => Transport::Poll.available(),
            Transport::Poll if !crate::util::net::supported() => Transport::Threaded,
            t => t,
        }
    }
}

/// Serving limits, all runtime-tunable (`Service` builder + CLI flags)
/// and reported by the `stats` op. Compile-time constants until PR 6;
/// a fleet-scale deployment tunes them per box instead of recompiling.
#[derive(Clone, Copy, Debug)]
pub struct ServiceLimits {
    /// Open-connection cap for the event-loop transports: past it the
    /// loop parks the listener and the kernel backlog takes the
    /// overflow (deferred, not dropped). Clamped at serve time to
    /// `RLIMIT_NOFILE` minus a reserve — see
    /// [`Service::effective_max_conns`].
    pub max_conns: usize,
    /// Reap a connection after this long with no socket progress and no
    /// request in flight. The event loop sweeps on a fraction of this
    /// period; the threaded transport applies it as the socket read
    /// timeout. Covers silently-dead peers (no FIN/RST ever arrives)
    /// and peers that stopped reading responses, so stale sockets
    /// cannot pin fds (or, at the cap, wedge the acceptor) forever.
    pub idle_timeout: Duration,
    /// Unflushed response bytes buffered per connection before the loop
    /// stops reading from and dispatching for it (write-side
    /// backpressure: a client that pipelines requests but never reads
    /// its responses cannot balloon server memory — the threaded path
    /// gets this for free from its blocking writes).
    pub max_wbuf: usize,
    /// Complete-but-undispatched frames buffered per connection before
    /// the loop stops reading from it (pipelining backpressure).
    pub max_pending: usize,
    /// Bounded post-stop drain: connections with a request in flight,
    /// pending frames, or unflushed response bytes get this long to
    /// finish before the loop closes them — a request that raced the
    /// shutdown still gets its reply. Bounded so a never-reading peer
    /// cannot stall shutdown.
    pub shutdown_drain: Duration,
}

impl Default for ServiceLimits {
    fn default() -> ServiceLimits {
        ServiceLimits {
            max_conns: 4096,
            idle_timeout: Duration::from_secs(300),
            max_wbuf: MAX_FRAME,
            max_pending: 64,
            shutdown_drain: Duration::from_secs(5),
        }
    }
}

/// Fds reserved out of `RLIMIT_NOFILE` for everything that is not a
/// client connection: listener, wake pipe, stdio, dataset files, and
/// slack for worker plumbing.
const FD_RESERVE: u64 = 64;

/// The soft `RLIMIT_NOFILE` where probeable (`None` off Unix or on
/// probe failure — no clamp is applied then).
fn nofile_soft_limit() -> Option<u64> {
    #[cfg(unix)]
    {
        crate::util::net::nofile_limit().map(|(soft, _)| soft)
    }
    #[cfg(not(unix))]
    {
        None
    }
}

pub struct Service {
    ds: Arc<OfflineDataset>,
    backend: Arc<dyn Backend + Send + Sync>,
    scheduler: Scheduler,
    conn_workers: usize,
    /// Reactor (event-loop) threads for the readiness transports; 0 =
    /// adaptive (`min(cores, 4)`).
    reactors: usize,
    /// How client sockets are served (best available by default).
    transport: Transport,
    /// Runtime-tunable serving limits (defaults match the former
    /// compile-time constants).
    limits: ServiceLimits,
    net: NetStats,
    /// Deadline applied to optimize requests that carry no
    /// `deadline_ms` of their own (`None` = unlimited).
    default_deadline: Option<Duration>,
    /// The live connection-worker pool while a readiness-driven serve
    /// is running — published so `stats` can report the priority lane's
    /// served count; `None` otherwise.
    conn_pool: Mutex<Option<Arc<WorkerTeam>>>,
}

/// Parsed + validated fields of one optimize request (the single source
/// of request defaults: target `cost`, method `cb-rbfopt`, budget 33,
/// seed 0, adaptive workers, `single_draw`).
struct OptimizeParams {
    workload: usize,
    workload_id: String,
    target: Target,
    method: String,
    budget: usize,
    seed: u64,
    /// 0 = adaptive (sized at execution time from in-flight load).
    trial_workers: usize,
    measure_mode: MeasureMode,
    /// Attach the convergence trace to the response. Like
    /// `trial_workers`, deliberately absent from [`ResponseKey`]: the
    /// trace is always computed and cached alongside the response, so
    /// requests differing only in this flag share one entry (and one
    /// trial).
    include_trace: bool,
    /// Per-request deadline in milliseconds (`None` = use the server's
    /// `default_deadline`, which may itself be unlimited). Absent from
    /// [`ResponseKey`]: cancelled responses are never cached, and a
    /// deadline that doesn't fire changes nothing about the answer.
    deadline_ms: Option<u64>,
    /// Dynamic-market online mode (`None` = static trial). Online
    /// responses bypass the response cache and batch dedup entirely:
    /// [`ResponseKey`] carries no market dimension, so an online
    /// response must never collide with (or serve) the static response
    /// of the same spec.
    online: Option<OnlineParams>,
    /// Attach the final-tick cost/runtime Pareto front to an online
    /// response.
    include_pareto: bool,
}

impl OptimizeParams {
    /// The response identity: everything that can change the answer.
    /// `trial_workers` is deliberately absent — worker counts never
    /// change results — so it also backs batch dedup at exactly the
    /// response-cache granularity.
    fn key(&self) -> ResponseKey {
        ResponseKey {
            workload: self.workload,
            target: self.target,
            method: self.method.clone(),
            budget: self.budget,
            seed: self.seed,
            mode: self.measure_mode,
        }
    }
}

impl Service {
    pub fn new(ds: Arc<OfflineDataset>, backend: Arc<dyn Backend + Send + Sync>) -> Service {
        Service {
            ds,
            backend,
            scheduler: Scheduler::new(DEFAULT_CACHE_CAP, DEFAULT_CACHE_SHARDS),
            conn_workers: default_workers().clamp(2, 32),
            reactors: 0,
            transport: Transport::best(),
            limits: ServiceLimits::default(),
            net: NetStats::new(),
            default_deadline: None,
            conn_pool: Mutex::new(None),
        }
    }

    /// Deadline applied to every optimize request that doesn't set its
    /// own `deadline_ms`. Zero disables the default (requests run to
    /// budget exhaustion unless they ask for a deadline themselves).
    pub fn with_default_deadline(mut self, deadline: Duration) -> Service {
        self.default_deadline = if deadline.is_zero() { None } else { Some(deadline) };
        self
    }

    /// The server-wide default deadline, if one is configured.
    pub fn default_deadline(&self) -> Option<Duration> {
        self.default_deadline
    }

    /// Control-plane requests served by the priority lane so far (0
    /// when no readiness-driven serve is live — the lane only exists
    /// inside the event loop's connection-worker pool).
    fn priority_served(&self) -> u64 {
        match &*self.conn_pool.lock().unwrap() {
            Some(pool) => pool.priority_served(),
            None => 0,
        }
    }

    /// Size the connection-worker pool. Under the event loop this bounds
    /// concurrently *executing* requests (open connections are decoupled
    /// from it); under the threaded fallback it bounds concurrently
    /// served connections, with further connections waiting in the
    /// accept queue.
    pub fn with_conn_workers(mut self, workers: usize) -> Service {
        self.conn_workers = workers.max(1);
        self
    }

    /// Reactor threads for the readiness transports (`0` = adaptive:
    /// `min(cores, 4)`, explicit values clamped to 1..=256). Each
    /// reactor owns its own readiness instance, wake pipe, outbox, and
    /// a disjoint subset of connections handed off at accept; the
    /// threaded transport ignores this knob.
    pub fn with_reactors(mut self, reactors: usize) -> Service {
        self.reactors = reactors.min(256);
        self
    }

    /// Reactor threads a readiness-driven serve will start: the
    /// explicit [`with_reactors`](Self::with_reactors) value, or
    /// `min(cores, 4)` when left adaptive.
    pub fn reactor_count(&self) -> usize {
        if self.reactors == 0 {
            default_workers().min(4).max(1)
        } else {
            self.reactors
        }
    }

    /// Choose the serving transport explicitly. An unavailable choice
    /// degrades to the nearest supported one (epoll → poll off Linux,
    /// poll → threaded off Unix) rather than failing: responses are
    /// byte-identical across all three, only scalability differs.
    pub fn with_transport(mut self, transport: Transport) -> Service {
        self.transport = transport.available();
        self
    }

    /// Compatibility switch predating [`with_transport`]
    /// (Self::with_transport): `true` = best readiness transport,
    /// `false` = thread-per-connection fallback.
    pub fn with_event_loop(mut self, on: bool) -> Service {
        self.transport = if on { Transport::best() } else { Transport::Threaded };
        self
    }

    /// The active serving transport.
    pub fn transport(&self) -> Transport {
        self.transport
    }

    /// Whether a readiness-driven (non-threaded) transport is active.
    pub fn event_loop_enabled(&self) -> bool {
        self.transport != Transport::Threaded
    }

    /// Cap simultaneously open connections on the event-loop transports
    /// (min 1; the threaded transport bounds concurrency by its worker
    /// pool instead). Further clamped to `RLIMIT_NOFILE` at serve time —
    /// see [`effective_max_conns`](Self::effective_max_conns).
    pub fn with_max_conns(mut self, cap: usize) -> Service {
        self.limits.max_conns = cap.max(1);
        self
    }

    /// Reap connections idle for this long (min 1 ms; also the threaded
    /// transport's socket read timeout).
    pub fn with_idle_timeout(mut self, timeout: Duration) -> Service {
        self.limits.idle_timeout = timeout.max(Duration::from_millis(1));
        self
    }

    /// Per-connection unflushed-response-byte cap before the loop stops
    /// reading from and dispatching for that connection (min 1).
    pub fn with_max_wbuf(mut self, bytes: usize) -> Service {
        self.limits.max_wbuf = bytes.max(1);
        self
    }

    /// Per-connection cap on buffered complete-but-undispatched frames
    /// (min 1): pipelining backpressure.
    pub fn with_max_pending(mut self, frames: usize) -> Service {
        self.limits.max_pending = frames.max(1);
        self
    }

    /// How long a stopping event loop keeps draining owed responses
    /// before closing the stragglers.
    pub fn with_shutdown_drain(mut self, drain: Duration) -> Service {
        self.limits.shutdown_drain = drain;
        self
    }

    /// The configured serving limits (as requested; the connection cap
    /// may be further clamped at serve time).
    pub fn limits(&self) -> &ServiceLimits {
        &self.limits
    }

    /// The connection cap actually enforced: the configured
    /// [`with_max_conns`](Self::with_max_conns) clamped to the
    /// `RLIMIT_NOFILE` soft limit minus a small fd reserve — so hitting the
    /// fd table shows up as one startup warning and a lower cap, not as
    /// opaque accept failures under load.
    pub fn effective_max_conns(&self) -> usize {
        let requested = self.limits.max_conns.max(1);
        match nofile_soft_limit() {
            Some(soft) => {
                let avail = soft.saturating_sub(FD_RESERVE).min(usize::MAX as u64) as usize;
                requested.min(avail.max(1))
            }
            None => requested,
        }
    }

    /// Bound the cross-request response cache (entries, min 1): beyond
    /// it the least-recently-used response in the affected stripe is
    /// evicted. Long-lived servers stay memory-bounded no matter how
    /// many distinct deterministic keys clients churn through. Rebuilds
    /// the stripes (dropping any cached entries), so set it before
    /// serving.
    pub fn with_cache_cap(mut self, cap: usize) -> Service {
        let shards = self.scheduler.cache.requested_shards;
        self.scheduler.cache = StripedCache::new(cap, shards);
        self
    }

    /// Stripe the response cache across `shards` independent LRU shards
    /// (default [`DEFAULT_CACHE_SHARDS`]; min 1, and never more than
    /// the cap so every stripe caps at ≥ 1 entry). One shard restores
    /// exact global LRU order; more shards trade that for uncontended
    /// concurrent lookups across reactors. Rebuilds the stripes
    /// (dropping any cached entries), so set it before serving.
    pub fn with_cache_shards(mut self, shards: usize) -> Service {
        let cap = self.scheduler.cache.cap;
        self.scheduler.cache = StripedCache::new(cap, shards);
        self
    }

    /// The shared request scheduler (stats + sizing).
    pub fn scheduler(&self) -> &Scheduler {
        &self.scheduler
    }

    /// Handle one request line; always returns a JSON response line.
    /// No connection backs this entry point, so only deadlines (not
    /// disconnects) can cancel work started here.
    pub fn handle(&self, line: &str) -> String {
        match parse(line) {
            Ok(req) => self.handle_value(&req, None),
            Err(e) => error_line(&format!("bad json: {e}")),
        }
    }

    /// Wire-level entry point: decode one extracted frame payload under
    /// `codec`, serve it, and return the encoded response frame. A
    /// protocol-fatal frame (e.g. non-UTF-8 under JSON lines) returns
    /// an empty buffer — transports answer those by closing. Both
    /// transports serve requests through this exact path; it is public
    /// so benches and differential tests can measure the codec seam
    /// without a socket.
    pub fn serve_frame(&self, frame: &[u8], codec: &'static dyn Codec) -> Vec<u8> {
        handle_wire(self, frame, codec, None).bytes
    }

    /// Dispatch one decoded request to a compact JSON response payload
    /// (the codec layer frames it for the wire). Top-level optimize
    /// requests are special-cased so deterministic repeats can be
    /// answered from the cache's pre-serialized string — no response
    /// `Value` is cloned or re-serialized on the hot path.
    fn handle_value(&self, req: &Value, cancel: Option<&CancelToken>) -> String {
        let op = req.get("op").and_then(|v| v.as_str()).unwrap_or("optimize");
        if op == "optimize" {
            return match self.parse_optimize(req) {
                Ok(p) => self.run_optimize_wire(p, cancel),
                Err(e) => error_line(&e),
            };
        }
        match self.handle_request(req, 0, cancel) {
            Ok(v) => v.to_string_compact(),
            Err(e) => error_line(&e),
        }
    }

    /// Serve a parsed optimize request as wire text. Deterministic
    /// requests that want no trace take the pre-serialized cache fast
    /// path: one LRU touch, one string clone, zero JSON work.
    fn run_optimize_wire(&self, p: OptimizeParams, cancel: Option<&CancelToken>) -> String {
        if p.measure_mode.deterministic() && !p.include_trace && p.online.is_none() {
            if let Some(hit) = self.scheduler.cache_lookup_str(&p.key()) {
                return hit;
            }
        }
        let include_trace = p.include_trace;
        let (resp, trace) = self.run_optimize_data(p, cancel);
        if include_trace {
            with_trace(&resp, &trace).to_string_compact()
        } else {
            resp.to_string_compact()
        }
    }

    /// Dispatch one parsed request. `depth` guards against nested batch
    /// ops (a batch entry may not itself be a batch). `cancel` is the
    /// requesting connection's token (None over `Service::handle`).
    fn handle_request(
        &self,
        req: &Value,
        depth: usize,
        cancel: Option<&CancelToken>,
    ) -> Result<Value, String> {
        let op = req.get("op").and_then(|v| v.as_str()).unwrap_or("optimize");
        match op {
            "ping" => Ok(Value::obj(vec![("ok", true.into()), ("pong", true.into())])),
            // Codec negotiation happens at the transport layer, and only
            // on a connection's first frame; a hello that reaches the
            // dispatcher arrived too late (or over `Service::handle`,
            // which has no connection to negotiate for).
            "hello" => Err("hello must be the first frame on a connection".into()),
            "list_workloads" => {
                let names: Vec<Value> =
                    self.ds.workloads.iter().map(|w| Value::str(w.id())).collect();
                Ok(Value::obj(vec![("ok", true.into()), ("workloads", Value::Arr(names))]))
            }
            "list_methods" => {
                let names: Vec<Value> =
                    ALL_OPTIMIZERS.iter().map(|m| Value::str(*m)).collect();
                Ok(Value::obj(vec![("ok", true.into()), ("methods", Value::Arr(names))]))
            }
            "stats" => {
                let s = &self.scheduler;
                // One locked snapshot backs every cache field, so the
                // reported counters are mutually consistent
                // (inserts - evictions == cached_responses) even under
                // concurrent load.
                let cache = s.cache_stats();
                let net = &self.net;
                // Per-reactor gauge snapshot: non-empty exactly while a
                // readiness-driven serve is live. `idle_connections` is
                // summed from the live gauges then (each reactor counts
                // only its own herd); open_connections stays a global
                // atomic because the acceptor maintains it for cap
                // enforcement.
                let gauges = net.reactor_gauges.lock().unwrap();
                let per_open: Vec<Value> = gauges
                    .iter()
                    .map(|g| g.open.load(Ordering::Relaxed).into())
                    .collect();
                let per_wakeups: Vec<Value> = gauges
                    .iter()
                    .map(|g| (g.wakeups.load(Ordering::Relaxed) as usize).into())
                    .collect();
                let idle = if gauges.is_empty() {
                    net.idle_connections.load(Ordering::Relaxed)
                } else {
                    gauges.iter().map(|g| g.idle.load(Ordering::Relaxed)).sum()
                };
                drop(gauges);
                let reactors =
                    if self.event_loop_enabled() { self.reactor_count() } else { 0 };
                Ok(Value::obj(vec![
                    ("ok", true.into()),
                    ("in_flight", s.in_flight().into()),
                    ("trials_run", (s.trials_run() as usize).into()),
                    ("cache_hits", (cache.hits as usize).into()),
                    ("cache_misses", (cache.misses as usize).into()),
                    ("cache_inserts", (cache.inserts as usize).into()),
                    ("cache_evictions", (cache.evictions as usize).into()),
                    ("cached_responses", cache.resident.into()),
                    ("cache_cap", s.cache.cap.into()),
                    ("cache_shards", s.cache_shards().into()),
                    ("team_threads", s.team_threads().into()),
                    ("conn_workers", self.conn_workers.into()),
                    ("reactors", reactors.into()),
                    ("per_reactor_open", Value::Arr(per_open)),
                    ("per_reactor_wakeups", Value::Arr(per_wakeups)),
                    ("transport", Value::str(self.transport.name())),
                    ("event_loop", self.event_loop_enabled().into()),
                    ("max_conns", self.effective_max_conns().into()),
                    ("max_conns_requested", self.limits.max_conns.into()),
                    ("idle_timeout_s", self.limits.idle_timeout.as_secs_f64().into()),
                    ("max_wbuf", self.limits.max_wbuf.into()),
                    ("max_pending", self.limits.max_pending.into()),
                    ("shutdown_drain_s", self.limits.shutdown_drain.as_secs_f64().into()),
                    (
                        "rlimit_nofile",
                        (nofile_soft_limit().unwrap_or(0).min(usize::MAX as u64) as usize).into(),
                    ),
                    ("open_connections", net.open_connections.load(Ordering::Relaxed).into()),
                    ("idle_connections", idle.into()),
                    ("loop_wakeups", (net.loop_wakeups.load(Ordering::Relaxed) as usize).into()),
                    ("ready_events", (net.ready_events.load(Ordering::Relaxed) as usize).into()),
                    (
                        "json_connections",
                        (net.json_connections.load(Ordering::Relaxed) as usize).into(),
                    ),
                    (
                        "binary_connections",
                        (net.binary_connections.load(Ordering::Relaxed) as usize).into(),
                    ),
                    (
                        "json_requests",
                        (net.json_requests.load(Ordering::Relaxed) as usize).into(),
                    ),
                    (
                        "binary_requests",
                        (net.binary_requests.load(Ordering::Relaxed) as usize).into(),
                    ),
                    (
                        "cancelled_disconnect",
                        (s.cancelled_disconnect() as usize).into(),
                    ),
                    ("cancelled_deadline", (s.cancelled_deadline() as usize).into()),
                    ("pulls_saved", (s.pulls_saved() as usize).into()),
                    ("priority_served", (self.priority_served() as usize).into()),
                    (
                        "default_deadline_ms",
                        self.default_deadline
                            .map(|d| d.as_millis() as usize)
                            .unwrap_or(0)
                            .into(),
                    ),
                ]))
            }
            "clear_cache" => {
                let cleared = self.scheduler.clear_cache();
                Ok(Value::obj(vec![("ok", true.into()), ("cleared", cleared.into())]))
            }
            "optimize" => self.handle_optimize(req, cancel),
            "batch" => {
                if depth > 0 {
                    return Err("batch requests cannot be nested".into());
                }
                let reqs = req
                    .get("requests")
                    .and_then(Value::as_arr)
                    .ok_or("batch needs a 'requests' array")?;
                if reqs.is_empty() {
                    return Err("batch 'requests' is empty".into());
                }
                if reqs.len() > MAX_BATCH {
                    return Err(format!("batch larger than {MAX_BATCH} requests"));
                }
                // Parse optimize entries once up front: the parse feeds
                // both dedup (pre-grouping identical deterministic keys
                // so each distinct key runs exactly one trial — a
                // guarantee, where relying on the response cache alone
                // would let racing duplicates both run) and execution
                // (representatives run from their parsed params, no
                // re-parse).
                let mut plans: Vec<Option<OptimizeParams>> = reqs
                    .iter()
                    .map(|r| match r.get("op").and_then(|v| v.as_str()) {
                        None | Some("optimize") => self.parse_optimize(r).ok(),
                        Some(_) => None,
                    })
                    .collect();
                let mut rep_of: Vec<usize> = Vec::with_capacity(reqs.len());
                let mut first_seen: HashMap<ResponseKey, usize> = HashMap::new();
                for (i, plan) in plans.iter().enumerate() {
                    // A slot with its own deadline never joins a dedup
                    // group: its cancellation must stay contained to its
                    // slot, not poison siblings sharing a representative
                    // (and a cancelled partial result must never be
                    // what the group's healthy slots receive). Online
                    // slots stay solo too — their key has no market
                    // dimension, so "identical key" does not mean
                    // "identical response".
                    match plan.as_ref().filter(|p| {
                        p.measure_mode.deterministic()
                            && p.deadline_ms.is_none()
                            && p.online.is_none()
                    }) {
                        Some(p) => rep_of.push(*first_seen.entry(p.key()).or_insert(i)),
                        None => rep_of.push(i),
                    }
                }
                // `include_trace` is outside the dedup key (the trace is
                // computed either way); remember which slots asked for it
                // before the plans are moved into the representatives.
                let want_trace: Vec<bool> =
                    plans.iter().map(|p| p.as_ref().is_some_and(|p| p.include_trace)).collect();
                // Fan the representative entries across the team; every
                // representative yields a response for its slot (errors
                // become error objects, never poison siblings).
                let uniques: Vec<(usize, Option<OptimizeParams>)> = (0..reqs.len())
                    .filter(|&i| rep_of[i] == i)
                    .map(|i| (i, plans[i].take()))
                    .collect();
                let slot_of: HashMap<usize, usize> =
                    uniques.iter().enumerate().map(|(s, &(i, _))| (i, s)).collect();
                let unique_responses: Vec<(Value, Option<Value>)> =
                    parallel_map_owned(uniques, default_workers(), |(i, plan)| {
                        // Contain panics per entry: one panicking trial
                        // must produce an error object in its own slot,
                        // not collapse the sibling responses.
                        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| match plan {
                            Some(p) => {
                                let (resp, trace) = self.run_optimize_data(p, cancel);
                                Ok((resp, Some(trace)))
                            }
                            None => {
                                self.handle_request(&reqs[i], depth + 1, cancel).map(|v| (v, None))
                            }
                        }))
                        .unwrap_or_else(|_| Err("internal error handling request".into()))
                        .unwrap_or_else(|e| {
                            (Value::obj(vec![("ok", false.into()), ("error", e.into())]), None)
                        })
                    });
                let responses: Vec<Value> = rep_of
                    .iter()
                    .enumerate()
                    .map(|(i, rep)| {
                        let (resp, trace) = &unique_responses[slot_of[rep]];
                        match trace {
                            Some(t) if want_trace[i] => with_trace(resp, t),
                            _ => resp.clone(),
                        }
                    })
                    .collect();
                Ok(Value::obj(vec![
                    ("ok", true.into()),
                    ("responses", Value::Arr(responses)),
                ]))
            }
            other => Err(format!("unknown op '{other}'")),
        }
    }

    /// Parse + validate an optimize request (also the batch-dedup
    /// front-end: validation must happen here so entries that would
    /// error never collapse onto a healthy representative).
    fn parse_optimize(&self, req: &Value) -> Result<OptimizeParams, String> {
        let workload_id = req
            .get("workload")
            .and_then(|v| v.as_str())
            .ok_or("missing 'workload'")?;
        let workload = self
            .ds
            .workload_index(workload_id)
            .ok_or_else(|| format!("unknown workload '{workload_id}'"))?;
        let target = Target::parse(
            req.get("target").and_then(|v| v.as_str()).unwrap_or("cost"),
        )
        .ok_or("target must be 'time' or 'cost'")?;
        let method = req
            .get("method")
            .and_then(|v| v.as_str())
            .unwrap_or("cb-rbfopt")
            .to_string();
        // Validate here: `run_trial` panics on unknown methods, and a
        // panic would kill a pooled connection worker.
        if !ALL_OPTIMIZERS.contains(&method.as_str()) && !PREDICTORS.contains(&method.as_str()) {
            return Err(format!("unknown method '{method}'"));
        }
        // Malformed numerics (negative, fractional, non-finite, or
        // beyond exact-integer range) are protocol errors — a request
        // that says `budget: -5` must hear so, not silently run with
        // the default and get its bogus value cached under it.
        let budget = match req.get("budget") {
            None => 33,
            Some(v) => v.as_usize().ok_or("budget must be a positive integer")?,
        };
        if budget == 0 || budget > 10_000 {
            return Err("budget out of range".into());
        }
        let seed = match req.get("seed") {
            None => 0,
            Some(v) => v.as_usize().ok_or("seed must be a non-negative integer")? as u64,
        };
        // 0 (or absent) = adaptive: sized at execution, after admission.
        let trial_workers = match req.get("trial_workers") {
            None => 0,
            Some(v) => v
                .as_usize()
                .ok_or("trial_workers must be a non-negative integer")?,
        };
        if trial_workers > MAX_TRIAL_WORKERS {
            return Err(format!(
                "trial_workers must be in 0..={MAX_TRIAL_WORKERS} (0 = adaptive)"
            ));
        }
        let measure_mode = match req.get("measure_mode") {
            None => MeasureMode::SingleDraw,
            Some(v) => {
                let s = v.as_str().ok_or("measure_mode must be a string")?;
                MeasureMode::parse(s).ok_or_else(|| {
                    format!("bad measure_mode '{s}' (single_draw | mean | p90)")
                })?
            }
        };
        let include_trace = match req.get("include_trace") {
            None => false,
            Some(v) => v.as_bool().ok_or("include_trace must be a boolean")?,
        };
        // 0 is allowed (an already-expired deadline): it deterministically
        // cancels after the guaranteed first pull, which is what the
        // deadline tests pin.
        let deadline_ms = match req.get("deadline_ms") {
            None => None,
            Some(v) => {
                let ms = v.as_usize().ok_or("deadline_ms must be a non-negative integer")? as u64;
                if ms > MAX_DEADLINE_MS {
                    return Err(format!("deadline_ms must be <= {MAX_DEADLINE_MS}"));
                }
                Some(ms)
            }
        };
        let online = OnlineParams::parse_field(req.get("online"))?;
        if online.is_some() && PREDICTORS.contains(&method.as_str()) {
            return Err(format!(
                "online mode requires search methods; '{method}' is a predictive baseline"
            ));
        }
        let include_pareto = match req.get("include_pareto") {
            None => false,
            Some(v) => v.as_bool().ok_or("include_pareto must be a boolean")?,
        };
        if include_pareto && online.is_none() {
            return Err("include_pareto requires online mode".into());
        }
        Ok(OptimizeParams {
            workload,
            workload_id: workload_id.to_string(),
            target,
            method,
            budget,
            seed,
            trial_workers,
            measure_mode,
            include_trace,
            deadline_ms,
            online,
            include_pareto,
        })
    }

    fn handle_optimize(&self, req: &Value, cancel: Option<&CancelToken>) -> Result<Value, String> {
        let p = self.parse_optimize(req)?;
        let include_trace = p.include_trace;
        let (resp, trace) = self.run_optimize_data(p, cancel);
        Ok(if include_trace { with_trace(&resp, &trace) } else { resp })
    }

    /// Bump the cancellation counters for one finished trial. A
    /// deadline is the request's own doing; every other reason
    /// (disconnect, shutdown, revocation mid-trial) means the work's
    /// requester or substrate went away.
    fn count_cancelled(&self, cancelled: Option<&'static str>, pulls_saved: usize) {
        if let Some(reason) = cancelled {
            let counter = if reason == CancelReason::Deadline.as_str() {
                &self.scheduler.cancelled_deadline
            } else {
                &self.scheduler.cancelled_disconnect
            };
            counter.fetch_add(1, Ordering::Relaxed);
            self.scheduler.pulls_saved.fetch_add(pulls_saved as u64, Ordering::Relaxed);
        }
    }

    /// Execute a parsed + validated optimize request (infallible past
    /// validation: cache hit or a real trial). Returns the base response
    /// plus the convergence trace — the caller attaches the trace only
    /// when its request asked for it, but the trace always travels with
    /// the cache entry so cached hits can answer `include_trace` too.
    ///
    /// `conn` is the requesting connection's cancel token (fired on
    /// disconnect/shutdown). The trial runs under a child of it so a
    /// per-request deadline can fire without touching the connection's
    /// other requests; a cancelled trial returns its completed prefix
    /// with a `cancelled` field and is never cached.
    fn run_optimize_data(&self, p: OptimizeParams, conn: Option<&CancelToken>) -> (Value, Value) {
        // Count this request in-flight from here on: the adaptive sizing
        // below divides the machine by what is actually running.
        let _admission = self.scheduler.admit();

        // Deterministic modes answer repeats from the response cache —
        // zero new measurements, byte-identical response. Online
        // requests always run: the key has no market dimension, so the
        // cache must neither serve nor store them.
        let key = p.key();
        if p.measure_mode.deterministic() && p.online.is_none() {
            if let Some(hit) = self.scheduler.cache_lookup(&key) {
                return (hit.resp, hit.trace);
            }
        }

        // The request's effective deadline: its own `deadline_ms`, else
        // the server default. No deadline and no connection = no token
        // (the trial is uncancellable, exactly the pre-cancellation
        // behavior).
        let deadline = p.deadline_ms.map(Duration::from_millis).or(self.default_deadline);
        let cancel: Option<CancelToken> = match (conn, deadline) {
            (None, None) => None,
            (conn, deadline) => {
                let token = match conn {
                    Some(parent) => parent.child(),
                    None => CancelToken::new(),
                };
                Some(match deadline {
                    Some(d) => token.with_deadline(Instant::now() + d),
                    None => token,
                })
            }
        };

        let trial_workers = if p.trial_workers == 0 {
            self.scheduler.effective_arm_workers()
        } else {
            p.trial_workers
        };
        let spec = TrialSpec {
            method: p.method,
            workload: p.workload,
            target: p.target,
            budget: p.budget,
            seed: p.seed,
            trial_workers,
            measure_mode: p.measure_mode,
        };

        // Online mode: run the dynamic-market loop and answer with the
        // regret-over-time shape. Never cached (see above), so the
        // response is built and returned directly.
        if let Some(params) = p.online {
            let out = run_online_trial_with(
                &self.ds,
                self.backend.as_ref(),
                &spec,
                &params,
                cancel.as_ref(),
            );
            self.scheduler.trials_run.fetch_add(1, Ordering::Relaxed);
            self.count_cancelled(out.result.cancelled, out.result.pulls_saved);
            let revocations: Vec<Value> =
                out.revocations.iter().map(|&t| (t as usize).into()).collect();
            let mut fields = vec![
                ("ok", true.into()),
                ("workload", p.workload_id.into()),
                ("target", p.target.name().into()),
                ("method", spec.method.as_str().into()),
                ("mode", Value::str("online")),
                ("ticks", out.regret_over_time.len().into()),
                ("value", out.result.chosen_value.into()),
                ("regret", out.result.regret.into()),
                ("evals", out.result.evals.into()),
                ("search_expense", out.result.search_expense.into()),
                ("reoptimizations", out.reoptimizations.into()),
                ("revocations", Value::Arr(revocations)),
            ];
            if let Some(reason) = out.result.cancelled {
                fields.push(("cancelled", reason.into()));
            }
            if p.include_pareto {
                let front: Vec<Value> = out
                    .pareto
                    .iter()
                    .map(|(label, time, cost)| {
                        Value::obj(vec![
                            ("config", Value::str(label)),
                            ("time", (*time).into()),
                            ("cost", (*cost).into()),
                        ])
                    })
                    .collect();
                fields.push(("pareto", Value::Arr(front)));
            }
            let resp = Value::obj(fields);
            let trace = Value::Arr(out.regret_over_time.iter().map(|&v| Value::Num(v)).collect());
            return (resp, trace);
        }

        let r = run_trial_with(&self.ds, self.backend.as_ref(), &spec, cancel.as_ref());
        self.scheduler.trials_run.fetch_add(1, Ordering::Relaxed);
        self.count_cancelled(r.cancelled, r.pulls_saved);
        let mut fields = vec![
            ("ok", true.into()),
            ("workload", p.workload_id.into()),
            ("target", p.target.name().into()),
            ("method", spec.method.as_str().into()),
            ("value", r.chosen_value.into()),
            ("regret", r.regret.into()),
            ("evals", r.evals.into()),
            ("search_expense", r.search_expense.into()),
        ];
        if let Some(reason) = r.cancelled {
            fields.push(("cancelled", reason.into()));
        }
        let resp = Value::obj(fields);
        let trace = Value::Arr(r.trace.iter().map(|&v| Value::Num(v)).collect());
        // Partial (cancelled) results never enter the cache: a later
        // identical request must run the full trial, and cached entries
        // stay byte-identical to complete uncancelled runs.
        if p.measure_mode.deterministic() && r.cancelled.is_none() {
            let entry = CachedResponse {
                resp: resp.clone(),
                resp_str: resp.to_string_compact(),
                trace: trace.clone(),
            };
            self.scheduler.cache_store(key, entry);
        }
        (resp, trace)
    }

    /// Serve until `stop` is set. Returns the bound local port.
    ///
    /// Transport is chosen by [`with_transport`](Self::with_transport):
    ///
    /// * **Event loop (epoll or poll; default on Unix)** — an acceptor
    ///   thread distributes sockets across
    ///   [`reactor_count`](Self::reactor_count) readiness-driven
    ///   reactor threads; complete request frames are handed to a
    ///   fixed pool of connection workers shared by all reactors and
    ///   responses written back nonblockingly. Idle keep-alive
    ///   connections never occupy a worker.
    /// * **Threaded fallback** — bounded accept queue (capacity 2× the
    ///   pool) drained by a fixed pool of persistent connection workers;
    ///   when the queue is full the acceptor stops draining the TCP
    ///   backlog — admission control instead of a thread per connection.
    pub fn serve(
        self: Arc<Self>,
        addr: &str,
        stop: Arc<AtomicBool>,
    ) -> std::io::Result<(u16, std::thread::JoinHandle<()>)> {
        let listener = TcpListener::bind(addr)?;
        let port = listener.local_addr()?.port();
        listener.set_nonblocking(true)?;
        let svc = self;
        #[cfg(unix)]
        if svc.transport != Transport::Threaded {
            let effective = svc.effective_max_conns();
            if effective < svc.limits.max_conns {
                eprintln!(
                    "service: max_conns {} exceeds RLIMIT_NOFILE soft limit {} minus reserve; \
                     capping open connections at {}",
                    svc.limits.max_conns,
                    nofile_soft_limit().unwrap_or(0),
                    effective,
                );
            }
            let handle = std::thread::spawn(move || event_loop::run(svc, listener, stop));
            return Ok((port, handle));
        }
        let handle = std::thread::spawn(move || serve_threaded(svc, listener, stop));
        Ok((port, handle))
    }
}

/// One response line for transport-level failures.
fn error_line(msg: &str) -> String {
    Value::obj(vec![("ok", false.into()), ("error", msg.into())]).to_string_compact()
}

/// Clone a response object with the convergence trace attached.
fn with_trace(resp: &Value, trace: &Value) -> Value {
    match resp {
        Value::Obj(kv) => {
            let mut kv = kv.clone();
            kv.push(("trace".to_string(), trace.clone()));
            Value::Obj(kv)
        }
        other => other.clone(),
    }
}

/// One framed reply travelling back to a connection: the bytes to write
/// and whether the connection closes once they are flushed. Empty bytes
/// with `close` set is the silent close (non-UTF-8 peer).
struct WireReply {
    bytes: Vec<u8>,
    close: bool,
}

/// Decode, dispatch, and re-frame one wire frame under `codec` — the
/// single request path both transports hand complete frames to.
/// `conn` is the owning connection's cancel token where the transport
/// has one (the event loop does; `Service::handle` and the threaded
/// transport, whose workers block in the request and cannot observe a
/// mid-request disconnect, pass `None` — deadlines still apply there).
fn handle_wire(
    svc: &Service,
    frame: &[u8],
    codec: &'static dyn Codec,
    conn: Option<&CancelToken>,
) -> WireReply {
    let text = match codec.decode_request(frame) {
        Ok(req) => {
            svc.net.count_request(codec);
            svc.handle_value(&req, conn)
        }
        Err(DecodeError::Malformed(e)) => {
            svc.net.count_request(codec);
            error_line(&format!("bad json: {e}"))
        }
        // The peer is not speaking this protocol: close cleanly without
        // a response (the pre-codec contract on both transports).
        Err(DecodeError::Fatal) => return WireReply { bytes: Vec::new(), close: true },
    };
    let mut bytes = Vec::with_capacity(text.len() + 8);
    codec.encode_frame(&text, &mut bytes);
    WireReply { bytes, close: false }
}

/// [`handle_wire`] with panics contained: the serving pools are
/// fixed-size, so a panic escaping a request would permanently shrink
/// them — it degrades to an error response instead.
fn handle_wire_guarded(
    svc: &Service,
    frame: &[u8],
    codec: &'static dyn Codec,
    conn: Option<&CancelToken>,
) -> WireReply {
    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| handle_wire(svc, frame, codec, conn)))
        .unwrap_or_else(|_| {
            let mut bytes = Vec::new();
            codec.encode_frame(&error_line("internal error handling request"), &mut bytes);
            WireReply { bytes, close: false }
        })
}

/// The thread-per-connection fallback acceptor (see [`Service::serve`]).
fn serve_threaded(svc: Arc<Service>, listener: TcpListener, stop: Arc<AtomicBool>) {
    let n_workers = svc.conn_workers.max(1);
    let (tx, rx) = sync_channel::<TcpStream>(2 * n_workers);
    let rx = Arc::new(Mutex::new(rx));
    let workers: Vec<_> = (0..n_workers)
        .map(|_| {
            let rx = Arc::clone(&rx);
            let svc = svc.clone();
            std::thread::spawn(move || loop {
                // Guard is a temporary: held while popping only.
                let conn = rx.lock().unwrap().recv();
                match conn {
                    Ok(stream) => {
                        svc.net.open_connections.fetch_add(1, Ordering::Relaxed);
                        let _ = handle_conn(&svc, stream);
                        svc.net.open_connections.fetch_sub(1, Ordering::Relaxed);
                    }
                    Err(_) => break, // acceptor gone: shutdown
                }
            })
        })
        .collect();

    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                let mut pending = Some(stream);
                while let Some(s) = pending.take() {
                    match tx.try_send(s) {
                        Ok(()) => {}
                        Err(TrySendError::Full(s)) => {
                            if stop.load(Ordering::Relaxed) {
                                break; // shed on shutdown
                            }
                            std::thread::sleep(std::time::Duration::from_millis(5));
                            pending = Some(s);
                        }
                        Err(TrySendError::Disconnected(_)) => break,
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
            Err(_) => break,
        }
    }
    drop(tx); // close the queue: workers drain and exit
    for w in workers {
        let _ = w.join();
    }
}

/// Serve one blocking connection on the shared [`FrameScanner`]: the
/// same framing, codec negotiation, and request path as the event loop,
/// with blocking reads/writes instead of readiness.
fn handle_conn(svc: &Service, stream: TcpStream) -> std::io::Result<()> {
    // The idle limit doubles as the read timeout here: an idle peer
    // trips it and the connection is reaped, matching the event loop.
    stream.set_read_timeout(Some(svc.limits.idle_timeout))?;
    let mut writer = stream.try_clone()?;
    let mut reader = stream;
    let mut scanner = FrameScanner::new();
    let mut greeted = false;
    let mut chunk = [0u8; 16 * 1024];
    loop {
        // Drain every complete frame before blocking on the socket.
        loop {
            let frame = match scanner.next_frame() {
                Ok(Some(frame)) => frame,
                Ok(None) => break,
                Err(_) => {
                    let mut out = Vec::new();
                    scanner
                        .codec()
                        .encode_frame(&error_line(&codec::oversize_message()), &mut out);
                    writer.write_all(&out)?;
                    writer.flush()?;
                    return Ok(());
                }
            };
            if !greeted {
                greeted = true;
                match codec::greet(&frame, scanner.codec()) {
                    Greeting::Request => svc.net.count_conn(scanner.codec()),
                    Greeting::Switch { reply, next } => {
                        writer.write_all(&reply)?;
                        writer.flush()?;
                        scanner.set_codec(next);
                        svc.net.count_conn(next);
                        continue;
                    }
                    Greeting::Reject { reply } => {
                        writer.write_all(&reply)?;
                        writer.flush()?;
                        return Ok(());
                    }
                }
            }
            let reply = handle_wire_guarded(svc, &frame, scanner.codec(), None);
            writer.write_all(&reply.bytes)?;
            writer.flush()?;
            if reply.close {
                return Ok(());
            }
        }
        match reader.read(&mut chunk) {
            // EOF: a trailing partial frame is discarded — its sender is
            // gone (mid-request disconnect), matching the event loop.
            Ok(0) => return Ok(()),
            Ok(n) => scanner.push(&chunk[..n]),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            // Read timeout (idle reap) or a dead peer: close.
            Err(_) => return Ok(()),
        }
    }
}

/// The readiness-driven transport, sharded into
/// [`Service::reactor_count`] reactor threads behind one
/// acceptor/distributor.
///
/// **Topology.** The acceptor is the only thread that touches the
/// listener: it accepts while the *global* open count is under the
/// effective [`ServiceLimits::max_conns`] (at the cap the listener is
/// parked — an interest transition — and the kernel backlog defers,
/// never drops, the overflow), makes each socket nonblocking, and hands
/// it to the least-loaded reactor's ingress queue (rotating tie-break,
/// so equal loads round-robin). Each reactor owns its own
/// [`Readiness`](crate::util::net::Readiness) instance (epoll or a
/// persistent poll set — [`Transport`] picks), its own wake pipe, its
/// own outbox, and the disjoint subset of connections it adopted — a
/// connection never migrates between reactors, which is what preserves
/// per-connection FIFO ordering and byte-identical transcripts across
/// reactor counts.
///
/// **Per reactor wakeup** (sockets register **once** at adoption;
/// interest changes only on state transitions, so steady-state
/// iterations touch only ready fds):
///
/// 1. waits for readiness (50 ms timeout to observe `stop`),
/// 2. drains the worker outbox (finished responses → per-connection
///    write buffers) and adopts sockets from its ingress queue,
/// 3. does nonblocking reads on readable connections, feeding each
///    one's shared [`FrameScanner`] and moving complete frames into
///    per-connection pending queues (codec negotiation resolves here,
///    on the first frame),
/// 4. dispatches at most **one** in-flight request per connection to
///    the shared connection-worker pool (strict per-connection FIFO —
///    the ordering contract of the threaded transport), and
/// 5. flushes write buffers nonblockingly, closing connections that
///    finished (`closing`/EOF with everything drained) and releasing
///    their global slot (waking a parked acceptor).
///
/// A wakeup costs O(ready events + adoptions) — under epoll,
/// independent of how many idle connections are open. Idle reaping
/// ([`ServiceLimits::idle_timeout`]) pops a per-reactor
/// deadline-ordered queue, so it costs O(expired connections) per
/// iteration — never a sweep over the open set.
///
/// Workers never touch sockets; reactors never run requests. They meet
/// only at each reactor's outbox (a mutex-guarded vec + a
/// [`WakePipe`]), so a slow trial can never stall reads, and 100k idle
/// keep-alive connections cost 100k fds — not 100k pinned threads.
/// Cross-reactor shared state is limited to the striped response
/// cache (lock per stripe), the worker pool's job queue, and a few
/// stats atomics.
#[cfg(unix)]
mod event_loop {
    use std::collections::{BTreeMap, BTreeSet, VecDeque};
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::{Arc, Mutex};
    use std::time::{Duration, Instant};

    use super::{
        error_line, handle_wire_guarded, NetStats, ReactorGauges, Service, ServiceLimits,
        Transport, WireReply, MAX_FRAME,
    };
    use crate::coordinator::codec::{self, FrameScanner, Greeting};
    use crate::util::cancel::{CancelReason, CancelToken};
    use crate::util::net::{poll, Event, PollFd, Readiness, WakePipe, POLLIN, POLLOUT};
    use crate::util::threadpool::WorkerTeam;

    /// Bytes pulled per readiness notification (level-triggered
    /// backends re-report leftover data, so one chunk per wakeup keeps
    /// the loop fair across connections).
    const READ_CHUNK: usize = 16 * 1024;
    /// Registration token of the worker-outbox wake pipe.
    const TOKEN_WAKE: u64 = 0;
    /// Registration token of the listener.
    const TOKEN_LISTENER: u64 = 1;
    /// First connection token (monotonic from here, never reused).
    const FIRST_CONN_TOKEN: u64 = 2;

    /// Per-connection state (the event loop's replacement for a pinned
    /// worker thread's stack).
    struct Conn {
        stream: TcpStream,
        /// The shared incremental framer; owns partial-frame bytes and
        /// the connection's negotiated codec.
        scanner: FrameScanner,
        /// First frame already classified by [`codec::greet`].
        greeted: bool,
        /// Response bytes not yet accepted by the socket.
        wbuf: Vec<u8>,
        wpos: usize,
        /// Complete frames awaiting dispatch (per-connection FIFO).
        pending: VecDeque<Vec<u8>>,
        /// One request is on the worker pool; its response not yet back.
        busy: bool,
        /// Close once `wbuf` drains (protocol error or shutdown path).
        closing: bool,
        /// Peer sent EOF: finish buffered work, then close.
        peer_closed: bool,
        /// Frame exceeded [`MAX_FRAME`]: emit one error (after pending
        /// responses, preserving order) and close.
        oversized: bool,
        /// Last socket progress (bytes read or written, or a response
        /// queued); drives the [`ServiceLimits::idle_timeout`] reap.
        last_activity: Instant,
        /// The deadline this connection is filed under in the reap
        /// queue (its entry is exactly `(reap_due, token)`).
        reap_due: Instant,
        /// Interest bits currently registered with the readiness
        /// backend; [`sync_conn`] issues a `modify` only when the
        /// desired interest departs from this (state transitions, not
        /// every iteration).
        interest: i16,
        /// Whether this connection is counted in the idle gauge —
        /// maintained incrementally by [`sync_conn`] so the gauge never
        /// needs an O(open connections) recount.
        counted_idle: bool,
        /// This connection's cancellation root: fired when the peer
        /// vanishes (EOF, hangup, error, reap, shutdown drain), so the
        /// request it has in flight stops pulling budget instead of
        /// running to completion for a reader that is gone. Requests run
        /// under a child of it.
        cancel: CancelToken,
    }

    impl Conn {
        fn new(stream: TcpStream) -> Conn {
            let now = Instant::now();
            Conn {
                stream,
                scanner: FrameScanner::new(),
                greeted: false,
                wbuf: Vec::new(),
                wpos: 0,
                pending: VecDeque::new(),
                busy: false,
                closing: false,
                peer_closed: false,
                oversized: false,
                last_activity: now,
                reap_due: now,
                interest: 0,
                counted_idle: false,
                cancel: CancelToken::new(),
            }
        }

        /// The peer is gone: remember it and fire the connection's
        /// cancel token so any in-flight request stops consuming budget
        /// at its next pull.
        fn mark_peer_closed(&mut self) {
            self.peer_closed = true;
            self.cancel.cancel(CancelReason::Disconnect);
        }

        /// Nothing buffered in either direction and no request running:
        /// the connection is an idle keep-alive costing one fd.
        fn idle(&self) -> bool {
            !self.busy
                && self.pending.is_empty()
                && self.scanner.buffered() == 0
                && self.wpos >= self.wbuf.len()
        }

        fn write_drained(&self) -> bool {
            self.wpos >= self.wbuf.len()
        }

        /// Finished: everything owed to the peer has been written.
        fn done(&self) -> bool {
            let drained =
                self.write_drained() && !self.busy && self.pending.is_empty() && !self.oversized;
            (self.closing && self.write_drained()) || (self.peer_closed && drained)
        }

        /// Unflushed response bytes awaiting the socket.
        fn wbuf_backlog(&self) -> usize {
            self.wbuf.len() - self.wpos
        }

        /// Stage one framed reply for the socket; a `close` reply also
        /// marks the connection closing (it still drains first).
        fn queue_reply(&mut self, reply: WireReply) {
            self.wbuf.extend_from_slice(&reply.bytes);
            if reply.close {
                self.closing = true;
            }
            self.last_activity = Instant::now();
        }
    }

    /// Finished replies travelling worker → reactor. Workers push and
    /// wake; the owning reactor drains under one lock acquisition per
    /// iteration. The wake pipe doubles as the reactor's hand-off
    /// doorbell: the acceptor rings it after queueing a socket.
    struct Outbox {
        queue: Mutex<Vec<(u64, WireReply)>>,
        wake: WakePipe,
    }

    impl Outbox {
        fn push(&self, token: u64, reply: WireReply) {
            self.queue.lock().unwrap().push((token, reply));
            self.wake.wake();
        }
    }

    /// Everything the acceptor shares with one reactor thread.
    struct ReactorShared {
        /// Accepted sockets handed off by the acceptor, adopted by the
        /// reactor at its next wakeup. A socket never moves again: the
        /// adopting reactor owns it until close, which is what keeps
        /// per-connection FIFO ordering and transcripts byte-identical
        /// to the single-reactor and threaded paths.
        ingress: Mutex<Vec<TcpStream>>,
        outbox: Arc<Outbox>,
        gauges: Arc<ReactorGauges>,
    }

    /// The reactors' channel back to the acceptor: while the listener
    /// is parked at the global connection cap, the reactor closing a
    /// connection rings this so the freed slot re-admits the kernel
    /// backlog promptly instead of waiting out the acceptor's 50 ms
    /// wait timeout.
    struct AcceptorLink {
        parked: AtomicBool,
        wake: WakePipe,
    }

    /// Close-time slot bookkeeping shared by every path that releases
    /// a connection: the per-reactor load gauge and the global open
    /// count move down together, and a parked acceptor is woken
    /// because the freed slot lets it accept again.
    struct SlotRelease<'a> {
        net: &'a NetStats,
        gauges: &'a ReactorGauges,
        link: &'a AcceptorLink,
    }

    impl SlotRelease<'_> {
        fn release(&self) {
            self.gauges.open.fetch_sub(1, Ordering::Relaxed);
            self.net.open_connections.fetch_sub(1, Ordering::Relaxed);
            if self.link.parked.load(Ordering::Relaxed) {
                self.link.wake.wake();
            }
        }
    }

    /// Start [`Service::reactor_count`] reactor threads, then run the
    /// acceptor/distributor on this thread until `stop`; joining the
    /// reactors (each runs its own bounded shutdown drain) and the
    /// shared worker pool on the way out.
    pub(super) fn run(svc: Arc<Service>, listener: TcpListener, stop: Arc<AtomicBool>) {
        let n = svc.reactor_count().max(1);
        let max_conns = svc.effective_max_conns();
        // One connection-worker pool shared by every reactor: request
        // concurrency stays bounded by `conn_workers` no matter how
        // many reactors dispatch into it. One extra priority-only
        // worker backs the high lane, so control-plane ops (`stats`,
        // `clear_cache`, ...) answer in bounded time even when every
        // normal worker is deep in a long trial. Published on the
        // service so `stats` can report `priority_served`.
        let pool = Arc::new(WorkerTeam::host_pool_with_priority(svc.conn_workers.max(1), 1));
        *svc.conn_pool.lock().unwrap() = Some(Arc::clone(&pool));
        let link = Arc::new(AcceptorLink {
            parked: AtomicBool::new(false),
            wake: WakePipe::new().expect("acceptor: wake pipe"),
        });
        let reactors: Vec<Arc<ReactorShared>> = (0..n)
            .map(|_| {
                Arc::new(ReactorShared {
                    ingress: Mutex::new(Vec::new()),
                    outbox: Arc::new(Outbox {
                        queue: Mutex::new(Vec::new()),
                        wake: WakePipe::new().expect("reactor: wake pipe"),
                    }),
                    gauges: Arc::new(ReactorGauges::new()),
                })
            })
            .collect();
        // Publish the per-reactor gauges so `stats` can report
        // `per_reactor_open` / `per_reactor_wakeups` while live.
        *svc.net.reactor_gauges.lock().unwrap() =
            reactors.iter().map(|r| Arc::clone(&r.gauges)).collect();

        let threads: Vec<_> = reactors
            .iter()
            .map(|shared| {
                let svc = Arc::clone(&svc);
                let shared = Arc::clone(shared);
                let link = Arc::clone(&link);
                let pool = Arc::clone(&pool);
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    reactor_loop(&svc, &shared, &link, &pool, &stop, max_conns)
                })
            })
            .collect();

        accept_loop(&svc, &listener, &reactors, &link, &stop, max_conns);

        // Stop observed: ring every reactor so none sits out its wait
        // timeout, then join them.
        for shared in &reactors {
            shared.outbox.wake.wake();
        }
        for t in threads {
            let _ = t.join();
        }
        *svc.conn_pool.lock().unwrap() = None;
        drop(pool); // last ref: join workers (in-flight requests finish)
        svc.net.reactor_gauges.lock().unwrap().clear();
        svc.net.open_connections.store(0, Ordering::Relaxed);
        svc.net.idle_connections.store(0, Ordering::Relaxed);
    }

    /// The acceptor/distributor: the only thread that touches the
    /// listener. It accepts while the *global* open count is under the
    /// effective cap — `RLIMIT_NOFILE` clamping and the at-cap
    /// listener-parking semantics are exactly the single-loop ones —
    /// and hands each socket to the least-loaded reactor's ingress
    /// queue (a rotating cursor breaks ties, so an idle server still
    /// round-robins instead of piling onto reactor 0).
    fn accept_loop(
        svc: &Service,
        listener: &TcpListener,
        reactors: &[Arc<ReactorShared>],
        link: &AcceptorLink,
        stop: &AtomicBool,
        max_conns: usize,
    ) {
        let mut reg = Readiness::poll_set().expect("acceptor: poll set");
        reg.register(link.wake.read_fd(), TOKEN_WAKE, POLLIN)
            .expect("acceptor: register wake pipe");
        reg.register(listener.as_raw_fd(), TOKEN_LISTENER, POLLIN)
            .expect("acceptor: register listener");
        let mut accepting = true;
        let mut events: Vec<Event> = Vec::new();
        let mut cursor = 0usize;
        while !stop.load(Ordering::Relaxed) {
            if reg.wait(&mut events, 50).is_err() {
                // A persistent wait failure (e.g. ENOMEM) must not
                // busy-spin the loop: back off for one wait period and
                // retry, still observing `stop`.
                std::thread::sleep(Duration::from_millis(50));
                continue;
            }
            link.wake.drain();
            loop {
                if svc.net.open_connections.load(Ordering::Relaxed) >= max_conns {
                    break;
                }
                match listener.accept() {
                    Ok((stream, _)) => {
                        if stream.set_nonblocking(true).is_err() {
                            continue;
                        }
                        // Round-robin-by-load: the hand-off is counted
                        // against the reactor's gauge *here*, so
                        // in-flight (not yet adopted) sockets already
                        // weigh in the next pick.
                        let pick = (0..reactors.len())
                            .map(|i| (cursor + i) % reactors.len())
                            .min_by_key(|&i| reactors[i].gauges.open.load(Ordering::Relaxed))
                            .unwrap_or(0);
                        cursor = (pick + 1) % reactors.len();
                        let shard = &reactors[pick];
                        svc.net.open_connections.fetch_add(1, Ordering::Relaxed);
                        shard.gauges.open.fetch_add(1, Ordering::Relaxed);
                        shard.ingress.lock().unwrap().push(stream);
                        shard.outbox.wake.wake();
                    }
                    Err(_) => break, // WouldBlock or transient error
                }
            }
            // Park/unpark the listener on cap transitions, so a full
            // house costs no accept wakeups and a freed slot re-admits
            // the kernel backlog (deferred, not dropped). `parked` is
            // what tells closing reactors to ring the wake pipe.
            let want_accept = svc.net.open_connections.load(Ordering::Relaxed) < max_conns;
            if want_accept != accepting {
                let flags = if want_accept { POLLIN } else { 0 };
                let _ = reg.modify(listener.as_raw_fd(), TOKEN_LISTENER, flags);
                accepting = want_accept;
            }
            link.parked.store(!accepting, Ordering::Relaxed);
        }
    }

    /// One reactor: owns its readiness instance, wake pipe, outbox, and
    /// a disjoint subset of connections (adopted from its ingress
    /// queue, never migrated). The body is the single-loop transport
    /// minus accepting — hand-off pickup replaces the listener — so
    /// every per-connection contract (FIFO dispatch, backpressure,
    /// idle reap, bounded drain) is verbatim.
    fn reactor_loop(
        svc: &Arc<Service>,
        shared: &ReactorShared,
        link: &AcceptorLink,
        pool: &Arc<WorkerTeam>,
        stop: &AtomicBool,
        max_conns: usize,
    ) {
        let limits = svc.limits;
        let outbox = &shared.outbox;
        let gauges = &*shared.gauges;
        let slot = SlotRelease { net: &svc.net, gauges, link };
        // The requested backend, degrading to the portable poll set if
        // epoll creation fails at runtime (e.g. fd exhaustion). The
        // epoll wait batch is sized to the connection cap (plus the
        // wake pipe), so a fully-active house drains in one syscall
        // instead of 1024-event slices.
        let mut reg = if svc.transport == Transport::Epoll {
            match Readiness::epoll_with_batch(max_conns + 2) {
                Some(Ok(r)) => r,
                _ => Readiness::poll_set().expect("reactor: poll set"),
            }
        } else {
            Readiness::poll_set().expect("reactor: poll set")
        };
        reg.register(outbox.wake.read_fd(), TOKEN_WAKE, POLLIN)
            .expect("reactor: register wake pipe");

        let mut conns: BTreeMap<u64, Conn> = BTreeMap::new();
        let mut next_token: u64 = FIRST_CONN_TOKEN;
        // Incremental idle gauge (see `Conn::counted_idle`).
        let mut idle_count: usize = 0;
        // Scratch buffers reused across iterations: readiness events,
        // tokens an event touched this iteration, tokens to close.
        let mut events: Vec<Event> = Vec::new();
        let mut touched: Vec<u64> = Vec::new();
        let mut dead: Vec<u64> = Vec::new();

        // Stale connections are reaped from a deadline-ordered queue:
        // each connection is filed under the earliest instant it could
        // expire, and every iteration pops only entries whose deadline
        // passed — re-arming those that made progress since. Reaping is
        // O(expired), never a sweep over 100k open sockets.
        let mut reap_queue: BTreeSet<(Instant, u64)> = BTreeSet::new();

        while !stop.load(Ordering::Relaxed) {
            if reg.wait(&mut events, 50).is_err() {
                std::thread::sleep(Duration::from_millis(50));
                continue;
            }
            if stop.load(Ordering::Relaxed) {
                break;
            }
            if !events.is_empty() {
                svc.net.loop_wakeups.fetch_add(1, Ordering::Relaxed);
                svc.net.ready_events.fetch_add(events.len() as u64, Ordering::Relaxed);
                gauges.wakeups.fetch_add(1, Ordering::Relaxed);
            }

            touched.clear();
            dead.clear();

            // 1. Classify events; read from readable connections.
            for ev in &events {
                match ev.token {
                    TOKEN_WAKE => outbox.wake.drain(),
                    tok => {
                        let Some(c) = conns.get_mut(&tok) else { continue };
                        if ev.error() {
                            dead.push(tok);
                            continue;
                        }
                        if ev.readable() {
                            if !read_ready(c, svc) {
                                dead.push(tok);
                                continue;
                            }
                        } else if ev.hangup() {
                            c.mark_peer_closed();
                        }
                        touched.push(tok);
                    }
                }
            }

            // 2. Worker replies. Drain the outbox unconditionally —
            // it is one uncontended lock when empty, and doing so makes
            // a missed wake merely a latency blip, never a stall.
            let finished: Vec<(u64, WireReply)> =
                std::mem::take(&mut *outbox.queue.lock().unwrap());
            for (tok, reply) in finished {
                // The connection may have died while its request ran;
                // the reply is then simply dropped.
                if let Some(c) = conns.get_mut(&tok) {
                    c.queue_reply(reply);
                    c.busy = false;
                    touched.push(tok);
                }
            }

            // 3. Adopt handed-off sockets: register once, watch for
            // requests. The acceptor already counted each against the
            // global cap and this reactor's load gauge (and made it
            // nonblocking), so a registration failure must release the
            // slot it holds.
            let arrivals: Vec<TcpStream> =
                std::mem::take(&mut *shared.ingress.lock().unwrap());
            for stream in arrivals {
                let tok = next_token;
                next_token += 1;
                let mut c = Conn::new(stream);
                if reg.register(c.stream.as_raw_fd(), tok, POLLIN).is_err() {
                    slot.release(); // drop the socket, keep serving
                    continue;
                }
                c.interest = POLLIN;
                c.reap_due = Instant::now() + limits.idle_timeout;
                reap_queue.insert((c.reap_due, tok));
                conns.insert(tok, c);
                touched.push(tok);
            }

            // Remove unrecoverable connections before dispatching, so no
            // request is handed to workers on behalf of a gone client.
            for tok in dead.drain(..) {
                drop_conn(&mut conns, tok, &mut reg, &mut idle_count, &mut reap_queue, &slot);
            }

            // 4–6. Dispatch, flush, and re-sync interest — but only for
            // connections something actually happened to. Untouched
            // connections cannot have become dispatchable (their state
            // is unchanged), so skipping them is what makes a wakeup
            // O(ready events).
            touched.sort_unstable();
            touched.dedup();
            for &tok in &touched {
                let Some(c) = conns.get_mut(&tok) else { continue };
                dispatch(c, tok, svc, pool, outbox);
                let alive = flush(c);
                if alive {
                    // Flushing may have drained the write backlog below
                    // the dispatch gate: admit the next pending frame
                    // now rather than waiting for another event.
                    dispatch(c, tok, svc, pool, outbox);
                }
                if !alive || c.done() {
                    dead.push(tok);
                } else {
                    sync_conn(c, tok, &mut reg, &limits, &mut idle_count);
                }
            }
            for tok in dead.drain(..) {
                drop_conn(&mut conns, tok, &mut reg, &mut idle_count, &mut reap_queue, &slot);
            }

            // Reap expired connections: pop due deadlines off the front
            // of the queue. A connection that made progress (or has a
            // request running) since its deadline was filed is re-armed
            // at the next instant it could actually expire, so each
            // connection costs O(log n) per idle_timeout of lifetime —
            // and an idle herd costs nothing until it expires.
            let now = Instant::now();
            while let Some(&(due, tok)) = reap_queue.iter().next() {
                if due > now {
                    break;
                }
                reap_queue.remove(&(due, tok));
                let Some(c) = conns.get_mut(&tok) else { continue };
                let deadline = c.last_activity + limits.idle_timeout;
                if c.busy || deadline > now {
                    c.reap_due = if c.busy { now + limits.idle_timeout } else { deadline };
                    reap_queue.insert((c.reap_due, tok));
                } else {
                    dead.push(tok);
                }
            }
            for tok in dead.drain(..) {
                drop_conn(&mut conns, tok, &mut reg, &mut idle_count, &mut reap_queue, &slot);
            }

            // This reactor's idle gauge for the `stats` op (`open`
            // moves incrementally at hand-off and close).
            gauges.idle.store(idle_count, Ordering::Relaxed);
        }

        // Post-stop drain (bounded): deliver what is owed — responses
        // for requests already running or queued, unflushed bytes —
        // then close. Idle keep-alives are shed immediately. Uses a
        // throwaway poll set per iteration (the survivor set is tiny
        // and shrinking; registration bookkeeping buys nothing here).
        // Slot bookkeeping is skipped: the coordinator zeroes every
        // gauge once all reactors have joined.
        let deadline = Instant::now() + limits.shutdown_drain;
        // Fire every live connection's token first: requests still
        // running stop pulling budget at their next pull and come back
        // as partial `cancelled:"shutdown"` responses — which is what
        // makes the drain *bounded* even when trials are long.
        for c in conns.values() {
            c.cancel.cancel(CancelReason::Shutdown);
        }
        while Instant::now() < deadline {
            conns.retain(|_, c| c.busy || !c.pending.is_empty() || c.wbuf_backlog() > 0);
            if conns.is_empty() {
                break;
            }
            let mut fds = Vec::with_capacity(conns.len() + 1);
            fds.push(PollFd::new(outbox.wake.read_fd(), POLLIN));
            for c in conns.values() {
                let events = if c.wbuf_backlog() > 0 { POLLOUT } else { 0 };
                fds.push(PollFd::new(c.stream.as_raw_fd(), events));
            }
            if poll(&mut fds, 50).is_err() {
                std::thread::sleep(std::time::Duration::from_millis(50));
            }
            if fds[0].readable() {
                outbox.wake.drain();
            }
            let finished: Vec<(u64, WireReply)> =
                std::mem::take(&mut *outbox.queue.lock().unwrap());
            for (tok, reply) in finished {
                if let Some(c) = conns.get_mut(&tok) {
                    c.queue_reply(reply);
                    c.busy = false;
                }
            }
            let mut dead: Vec<u64> = Vec::new();
            for (tok, c) in conns.iter_mut() {
                dispatch(c, *tok, svc, pool, outbox);
                if !flush(c) {
                    dead.push(*tok);
                }
            }
            for tok in dead {
                conns.remove(&tok);
            }
        }

        drop(conns); // close any socket still unfinished at the deadline
        gauges.idle.store(0, Ordering::Relaxed);
    }

    /// Pull readable bytes and slice complete frames into `pending`.
    /// Returns `false` when the connection is unrecoverable.
    fn read_ready(c: &mut Conn, svc: &Service) -> bool {
        let mut chunk = [0u8; READ_CHUNK];
        loop {
            match c.stream.read(&mut chunk) {
                Ok(0) => {
                    c.mark_peer_closed();
                    break;
                }
                Ok(n) => {
                    c.scanner.push(&chunk[..n]);
                    c.last_activity = Instant::now();
                    extract_frames(c, svc);
                    // One chunk per readiness keeps the loop fair;
                    // level-triggered poll re-reports leftovers.
                    break;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => return false,
            }
        }
        true
    }

    /// Move complete frames out of the shared scanner into `pending`,
    /// resolving codec negotiation on the first frame — it must happen
    /// here, not at dispatch, because the scanner eagerly drains
    /// everything buffered: a pipelined `hello` + binary burst in one
    /// segment must switch codecs before the remaining bytes are
    /// scanned. An oversize flags the connection; `dispatch` emits the
    /// one shared error message after earlier responses, in order.
    fn extract_frames(c: &mut Conn, svc: &Service) {
        if c.oversized || c.closing {
            c.scanner.clear();
            return;
        }
        loop {
            match c.scanner.next_frame() {
                Ok(None) => break,
                Ok(Some(frame)) => {
                    if !c.greeted {
                        c.greeted = true;
                        match codec::greet(&frame, c.scanner.codec()) {
                            Greeting::Request => svc.net.count_conn(c.scanner.codec()),
                            Greeting::Switch { reply, next } => {
                                c.queue_reply(WireReply { bytes: reply, close: false });
                                c.scanner.set_codec(next);
                                svc.net.count_conn(next);
                                continue;
                            }
                            Greeting::Reject { reply } => {
                                c.queue_reply(WireReply { bytes: reply, close: true });
                                c.scanner.clear();
                                return;
                            }
                        }
                    }
                    c.pending.push_back(frame);
                }
                Err(_) => {
                    c.oversized = true;
                    c.scanner.clear();
                    return;
                }
            }
        }
    }

    /// The interest bits this connection's state calls for right now:
    /// read while the peer may send more and no backpressure gate is
    /// tripped (pipelining depth, frame size, write backlog); write
    /// while response bytes await the socket.
    fn desired_interest(c: &Conn, limits: &ServiceLimits) -> i16 {
        let mut want = 0i16;
        let readable_wanted = !c.peer_closed
            && !c.closing
            && !c.oversized
            && c.pending.len() < limits.max_pending
            && c.scanner.buffered() <= MAX_FRAME
            && c.wbuf_backlog() <= limits.max_wbuf;
        if readable_wanted {
            want |= POLLIN;
        }
        if !c.write_drained() {
            want |= POLLOUT;
        }
        want
    }

    /// Re-sync a just-touched connection with the readiness backend and
    /// the idle gauge. Interest is modified only on an actual transition
    /// (registration is the point of the epoll backend; for the poll
    /// set it is one in-place slot write), and the idle gauge moves
    /// only when the connection's idleness flips.
    fn sync_conn(
        c: &mut Conn,
        token: u64,
        reg: &mut Readiness,
        limits: &ServiceLimits,
        idle_count: &mut usize,
    ) {
        let want = desired_interest(c, limits);
        if want != c.interest && reg.modify(c.stream.as_raw_fd(), token, want).is_ok() {
            c.interest = want;
        }
        let is_idle = c.idle();
        if is_idle != c.counted_idle {
            if is_idle {
                *idle_count += 1;
            } else {
                *idle_count -= 1;
            }
            c.counted_idle = is_idle;
        }
    }

    /// Close a connection: deregister from the backend, correct the
    /// idle gauge and reap queue, release its global/per-reactor slot
    /// (waking a parked acceptor), drop the socket.
    fn drop_conn(
        conns: &mut BTreeMap<u64, Conn>,
        token: u64,
        reg: &mut Readiness,
        idle_count: &mut usize,
        reap_queue: &mut BTreeSet<(Instant, u64)>,
        slot: &SlotRelease<'_>,
    ) {
        if let Some(c) = conns.remove(&token) {
            // Whatever request is still running for this connection has
            // no reader anymore: stop it at its next pull.
            c.cancel.cancel(CancelReason::Disconnect);
            let _ = reg.deregister(c.stream.as_raw_fd(), token);
            reap_queue.remove(&(c.reap_due, token));
            if c.counted_idle {
                *idle_count -= 1;
            }
            slot.release();
        }
    }

    /// Hand the next pending frame (if any, and none is in flight) to
    /// the worker pool; emit the deferred oversize error once the queue
    /// drains so responses keep request order. Decoding happens on the
    /// worker ([`handle_wire_guarded`]), never on the loop thread.
    /// Control-plane frames ride the pool's high-priority lane so
    /// `stats`/`clear_cache` answer in bounded time while every normal
    /// worker is deep in a long trial.
    fn dispatch(
        c: &mut Conn,
        token: u64,
        svc: &Arc<Service>,
        pool: &WorkerTeam,
        outbox: &Arc<Outbox>,
    ) {
        if !c.busy && !c.closing && c.wbuf_backlog() <= svc.limits.max_wbuf {
            let Some(frame) = c.pending.pop_front() else {
                if c.oversized {
                    let mut bytes = Vec::new();
                    c.scanner
                        .codec()
                        .encode_frame(&error_line(&codec::oversize_message()), &mut bytes);
                    c.queue_reply(WireReply { bytes, close: true });
                    c.oversized = false;
                }
                return;
            };
            c.busy = true;
            let high = is_priority_frame(&frame);
            let conn_codec = c.scanner.codec();
            let cancel = c.cancel.clone();
            let svc = Arc::clone(svc);
            let outbox = Arc::clone(outbox);
            let job = move || {
                let reply = handle_wire_guarded(&svc, &frame, conn_codec, Some(&cancel));
                outbox.push(token, reply);
            };
            if high {
                pool.execute_high(job);
            } else {
                pool.execute(job);
            }
        }
    }

    /// Cheap byte-level sniff for control-plane ops that should jump
    /// the queue (both codecs carry a JSON payload, so one scan covers
    /// them). A misclassification only affects cross-connection
    /// scheduling fairness — the frame is decoded and validated on the
    /// worker either way — so a heuristic is safe here.
    pub(super) fn is_priority_frame(frame: &[u8]) -> bool {
        const FAST_OPS: [&[u8]; 6] = [
            b"stats",
            b"ping",
            b"clear_cache",
            b"hello",
            b"list_workloads",
            b"list_methods",
        ];
        let Some(key) = frame.windows(4).position(|w| w == b"\"op\"") else {
            return false;
        };
        let mut i = key + 4;
        while i < frame.len() && frame[i].is_ascii_whitespace() {
            i += 1;
        }
        if i >= frame.len() || frame[i] != b':' {
            return false;
        }
        i += 1;
        while i < frame.len() && frame[i].is_ascii_whitespace() {
            i += 1;
        }
        if i >= frame.len() || frame[i] != b'"' {
            return false;
        }
        i += 1;
        FAST_OPS.iter().any(|op| {
            frame.len() >= i + op.len() + 1
                && &frame[i..i + op.len()] == *op
                && frame[i + op.len()] == b'"'
        })
    }

    /// Nonblocking write of whatever the socket will take. Returns
    /// `false` when the connection is unrecoverable.
    fn flush(c: &mut Conn) -> bool {
        while c.wpos < c.wbuf.len() {
            match c.stream.write(&c.wbuf[c.wpos..]) {
                Ok(0) => return false,
                Ok(n) => {
                    c.wpos += n;
                    c.last_activity = Instant::now();
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => return false,
            }
        }
        if c.wpos >= c.wbuf.len() {
            c.wbuf.clear();
            c.wpos = 0;
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::surrogate::NativeBackend;

    fn service() -> Service {
        let ds = Arc::new(OfflineDataset::generate(60, 3));
        Service::new(ds, Arc::new(NativeBackend))
    }

    #[test]
    fn ping_and_lists() {
        let svc = service();
        assert!(svc.handle(r#"{"op":"ping"}"#).contains("pong"));
        let w = svc.handle(r#"{"op":"list_workloads"}"#);
        assert!(w.contains("kmeans:santander"), "{w}");
        let m = svc.handle(r#"{"op":"list_methods"}"#);
        assert!(m.contains("cb-rbfopt"), "{m}");
        let s = svc.handle(r#"{"op":"stats"}"#);
        assert!(s.contains("team_threads"), "{s}");
    }

    #[test]
    fn optimize_request_roundtrip() {
        let svc = service();
        let resp = svc.handle(
            r#"{"op":"optimize","workload":"xgboost:credit_card","target":"cost","method":"rs","budget":11,"seed":3}"#,
        );
        let v = parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{resp}");
        assert_eq!(v.get("evals").unwrap().as_usize(), Some(11));
        assert!(v.get("value").unwrap().as_f64().unwrap() > 0.0);
    }

    /// `trial_workers` changes request latency, never the answer — and
    /// leaving it unset (adaptive sizing) answers identically too.
    #[test]
    fn parallel_optimize_requests_match_sequential() {
        let svc = service();
        let req = |workers: &str| {
            format!(
                r#"{{"op":"optimize","workload":"kmeans:buzz","target":"cost","method":"cb-rbfopt","budget":22,"seed":5{workers}}}"#
            )
        };
        let seq = svc.handle(&req(r#","trial_workers":1"#));
        let par = svc.handle(&req(r#","trial_workers":4"#));
        let adaptive = svc.handle(&req(""));
        let auto = svc.handle(&req(r#","trial_workers":0"#));
        assert!(seq.contains("\"ok\":true") || seq.contains("\"ok\": true"), "{seq}");
        assert_eq!(seq, par, "trial_workers changed the response");
        assert_eq!(seq, adaptive, "adaptive sizing changed the response");
        assert_eq!(seq, auto, "trial_workers=0 changed the response");
    }

    #[test]
    fn mean_mode_requests_run_memoized() {
        let svc = service();
        let resp = svc.handle(
            r#"{"op":"optimize","workload":"kmeans:buzz","target":"cost","method":"cherrypick-x1","budget":95,"seed":2,"measure_mode":"mean"}"#,
        );
        let v = parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{resp}");
        assert_eq!(v.get("evals").unwrap().as_usize(), Some(95));
    }

    /// The cross-request cache: a repeated deterministic-mode request is
    /// answered byte-identically with zero new source measurements; a
    /// `single_draw` request is never cached.
    #[test]
    fn repeated_deterministic_request_is_served_from_cache() {
        let svc = service();
        let req = r#"{"op":"optimize","workload":"kmeans:buzz","target":"cost","method":"rs","budget":14,"seed":7,"measure_mode":"mean"}"#;
        let first = svc.handle(req);
        assert!(first.contains("\"ok\":true"), "{first}");
        assert_eq!(svc.scheduler().cache_hits(), 0);
        let trials_before = svc.scheduler().trials_run();
        let reads_before = svc.ds.measurement_reads();
        let second = svc.handle(req);
        assert_eq!(first, second, "cached response must be byte-identical");
        assert_eq!(svc.scheduler().cache_hits(), 1, "second request must hit the cache");
        assert_eq!(svc.scheduler().trials_run(), trials_before, "no new trial");
        assert_eq!(
            svc.ds.measurement_reads(),
            reads_before,
            "cached response performed source measurements"
        );
        // Same key fields but a different seed is a different entry.
        let other = svc.handle(
            r#"{"op":"optimize","workload":"kmeans:buzz","target":"cost","method":"rs","budget":14,"seed":8,"measure_mode":"mean"}"#,
        );
        assert!(other.contains("\"ok\":true"));
        assert_eq!(svc.scheduler().cache_hits(), 1);
        // SingleDraw is uncacheable: repeating it runs a fresh trial.
        let sd = r#"{"op":"optimize","workload":"kmeans:buzz","target":"cost","method":"rs","budget":5,"seed":7}"#;
        let a = svc.handle(sd);
        let trials_mid = svc.scheduler().trials_run();
        let b = svc.handle(sd);
        assert_eq!(a, b, "SingleDraw is still deterministic per spec");
        assert_eq!(svc.scheduler().trials_run(), trials_mid + 1, "SingleDraw reruns");
        assert_eq!(svc.scheduler().cache_hits(), 1);
    }

    /// The LRU cap: the cache never exceeds it, evicts the stalest key,
    /// and a hit refreshes recency (so the hot key survives churn).
    /// One stripe makes eviction order exact global LRU, which is what
    /// the step-by-step assertions below pin.
    #[test]
    fn response_cache_evicts_least_recently_used_at_cap() {
        let svc = service().with_cache_cap(2).with_cache_shards(1);
        let req = |seed: usize| {
            format!(
                r#"{{"op":"optimize","workload":"kmeans:buzz","target":"cost","method":"rs","budget":6,"seed":{seed},"measure_mode":"mean"}}"#
            )
        };
        svc.handle(&req(1)); // cache: [1]
        svc.handle(&req(2)); // cache: [1, 2]
        assert_eq!(svc.scheduler().cached_responses(), 2);
        assert_eq!(svc.scheduler().cache_evictions(), 0);
        // Touch 1 so 2 becomes the LRU victim, then insert 3.
        svc.handle(&req(1));
        assert_eq!(svc.scheduler().cache_hits(), 1);
        svc.handle(&req(3)); // evicts 2 -> cache: [1, 3]
        assert_eq!(svc.scheduler().cached_responses(), 2, "cap must hold");
        assert_eq!(svc.scheduler().cache_evictions(), 1);
        // 1 and 3 still hit; 2 reruns the trial.
        let trials = svc.scheduler().trials_run();
        svc.handle(&req(1));
        svc.handle(&req(3));
        assert_eq!(svc.scheduler().trials_run(), trials, "1 and 3 must still be cached");
        svc.handle(&req(2));
        assert_eq!(svc.scheduler().trials_run(), trials + 1, "2 was evicted and reruns");
        // The stats op reports the new counters.
        let stats = svc.handle(r#"{"op":"stats"}"#);
        let v = parse(&stats).unwrap();
        assert_eq!(v.get("cache_cap").unwrap().as_usize(), Some(2), "{stats}");
        assert!(v.get("cache_evictions").unwrap().as_usize().unwrap() >= 1, "{stats}");
    }

    /// `clear_cache` drops every cached response (reporting the count)
    /// and subsequent repeats rerun their trials.
    #[test]
    fn clear_cache_op_empties_the_response_cache() {
        let svc = service();
        let req = r#"{"op":"optimize","workload":"kmeans:buzz","target":"cost","method":"rs","budget":6,"seed":1,"measure_mode":"mean"}"#;
        svc.handle(req);
        assert_eq!(svc.scheduler().cached_responses(), 1);
        let resp = svc.handle(r#"{"op":"clear_cache"}"#);
        let v = parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{resp}");
        assert_eq!(v.get("cleared").unwrap().as_usize(), Some(1), "{resp}");
        assert_eq!(svc.scheduler().cached_responses(), 0);
        let trials = svc.scheduler().trials_run();
        svc.handle(req);
        assert_eq!(svc.scheduler().trials_run(), trials + 1, "cleared key must rerun");
        // Clearing an empty cache is a no-op reporting 0... after the
        // rerun repopulated one entry.
        let again = svc.handle(r#"{"op":"clear_cache"}"#);
        assert_eq!(parse(&again).unwrap().get("cleared").unwrap().as_usize(), Some(1));
    }

    /// `include_trace` returns the ledger's convergence curve — on the
    /// cold run, on a cached hit, and even when the entry was cached by
    /// a request that never asked for the trace. Cached and cold traces
    /// are byte-identical.
    #[test]
    fn include_trace_returns_the_convergence_trace_cold_and_cached() {
        let svc = service();
        let traced = r#"{"op":"optimize","workload":"kmeans:buzz","target":"cost","method":"rs","budget":9,"seed":3,"measure_mode":"mean","include_trace":true}"#;
        let plain = r#"{"op":"optimize","workload":"kmeans:buzz","target":"cost","method":"rs","budget":9,"seed":3,"measure_mode":"mean"}"#;

        let cold = svc.handle(traced);
        let v = parse(&cold).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{cold}");
        let trace = v.get("trace").unwrap().as_arr().unwrap();
        assert_eq!(trace.len(), 9, "one best-so-far point per evaluation");
        let vals: Vec<f64> = trace.iter().map(|t| t.as_f64().unwrap()).collect();
        assert!(vals.windows(2).all(|w| w[1] <= w[0]), "trace must be non-increasing: {vals:?}");
        assert!(vals.iter().all(|x| x.is_finite() && *x > 0.0));

        // Cached hit: byte-identical, including the trace.
        let cached = svc.handle(traced);
        assert_eq!(cold, cached, "cached trace must equal the cold trace");
        assert_eq!(svc.scheduler().cache_hits(), 1);

        // The plain response has no trace field but shares the entry.
        let trials = svc.scheduler().trials_run();
        let plain_resp = svc.handle(plain);
        assert!(parse(&plain_resp).unwrap().get("trace").is_none(), "{plain_resp}");
        assert_eq!(svc.scheduler().trials_run(), trials, "same key: no new trial");

        // A cache entry stored *without* the flag still serves the
        // trace when a later request asks for it.
        let svc2 = service();
        svc2.handle(plain);
        let trials2 = svc2.scheduler().trials_run();
        let traced_from_cache = svc2.handle(traced);
        assert_eq!(svc2.scheduler().trials_run(), trials2, "trace served from cache");
        assert_eq!(svc2.scheduler().cache_hits(), 1);
        assert_eq!(traced_from_cache, cold, "trace must not depend on who populated the cache");

        // SingleDraw (uncached) requests also carry a trace on demand.
        let sd = svc.handle(
            r#"{"op":"optimize","workload":"kmeans:buzz","target":"cost","method":"rs","budget":5,"seed":1,"include_trace":true}"#,
        );
        let sd_trace = parse(&sd).unwrap().get("trace").unwrap().as_arr().unwrap().len();
        assert_eq!(sd_trace, 5);
    }

    /// Batch slots control `include_trace` individually while still
    /// deduping onto one trial per response key.
    #[test]
    fn batch_slots_attach_traces_per_request() {
        let det = r#"{"op":"optimize","workload":"kmeans:buzz","method":"rs","budget":7,"seed":1,"measure_mode":"mean"}"#;
        let det_traced = r#"{"op":"optimize","workload":"kmeans:buzz","method":"rs","budget":7,"seed":1,"measure_mode":"mean","include_trace":true}"#;
        let svc = service();
        let batch = format!(r#"{{"op":"batch","requests":[{det},{det_traced},{det}]}}"#);
        let v = parse(&svc.handle(&batch)).unwrap();
        let responses = v.get("responses").unwrap().as_arr().unwrap();
        assert_eq!(svc.scheduler().trials_run(), 1, "one key, one trial");
        assert!(responses[0].get("trace").is_none());
        assert!(responses[2].get("trace").is_none());
        let t = responses[1].get("trace").unwrap().as_arr().unwrap();
        assert_eq!(t.len(), 7);
        // Slots 0 and 2 are identical; slot 1 is slot 0 plus the trace.
        let base = responses[0].to_string_compact();
        let traced = responses[1].to_string_compact();
        assert_eq!(base, responses[2].to_string_compact());
        assert!(traced.starts_with(base.trim_end_matches('}')), "{traced} vs {base}");
    }

    /// Identical deterministic entries inside one batch run exactly one
    /// trial (pre-grouped, not cache-raced) — including entries that are
    /// only *semantically* identical (different `trial_workers`, key
    /// order, or number spelling); `single_draw` duplicates still run
    /// per slot.
    #[test]
    fn batch_dedups_identical_deterministic_entries() {
        let det = r#"{"op":"optimize","workload":"kmeans:buzz","method":"rs","budget":7,"seed":1,"measure_mode":"mean"}"#;
        // Same response key as `det`: worker count is not part of the
        // response identity, and the textual shape differs.
        let det_tw = r#"{"op":"optimize","method":"rs","workload":"kmeans:buzz","budget":7,"seed":1.0,"measure_mode":"mean","trial_workers":2}"#;
        let sd = r#"{"op":"optimize","workload":"kmeans:buzz","method":"rs","budget":7,"seed":1}"#;
        let svc = service();
        let batch =
            format!(r#"{{"op":"batch","requests":[{det},{det},{sd},{det_tw},{sd}]}}"#);
        let resp = svc.handle(&batch);
        let v = parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{resp}");
        let responses = v.get("responses").unwrap().as_arr().unwrap();
        assert_eq!(responses.len(), 5);
        // 1 trial for the three semantically-equal deterministic slots +
        // 2 for the single_draw slots.
        assert_eq!(svc.scheduler().trials_run(), 3, "deterministic dup must run once");
        for (i, j) in [(0usize, 1usize), (0, 3)] {
            assert_eq!(
                responses[i].to_string_compact(),
                responses[j].to_string_compact(),
                "deduped slots must carry the representative's response"
            );
        }
        // Parity with individual requests on a fresh service.
        let fresh = service();
        assert_eq!(responses[0].to_string_compact(), fresh.handle(det));
        assert_eq!(responses[2].to_string_compact(), fresh.handle(sd));
        // An entry that would error (invalid trial_workers) never
        // collapses onto a healthy representative.
        let bad_tw = r#"{"op":"optimize","workload":"kmeans:buzz","method":"rs","budget":7,"seed":1,"measure_mode":"mean","trial_workers":9999}"#;
        let batch2 = format!(r#"{{"op":"batch","requests":[{det},{bad_tw}]}}"#);
        let v2 = parse(&svc.handle(&batch2)).unwrap();
        let r2 = v2.get("responses").unwrap().as_arr().unwrap();
        assert_eq!(r2[0].get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(r2[1].get("ok").unwrap().as_bool(), Some(false), "invalid entry must error");
    }

    /// N client threads hammering one Service with a mixed op workload
    /// get responses byte-identical to serial execution on a fresh
    /// service.
    #[test]
    fn concurrent_mixed_ops_match_serial_execution() {
        let mixed: Vec<String> = {
            let mut v = vec![
                r#"{"op":"ping"}"#.to_string(),
                r#"{"op":"list_workloads"}"#.to_string(),
                r#"{"op":"list_methods"}"#.to_string(),
                r#"{"op":"optimize","workload":"kmeans:buzz","method":"rs","budget":9,"seed":1}"#.to_string(),
                r#"{"op":"optimize","workload":"kmeans:buzz","method":"cb-rbfopt","budget":11,"seed":2,"trial_workers":2}"#.to_string(),
                r#"{"op":"optimize","workload":"xgboost:credit_card","method":"rb","budget":12,"seed":3,"measure_mode":"mean"}"#.to_string(),
                r#"{"op":"optimize","workload":"kmeans:buzz","method":"cherrypick-x3","budget":10,"seed":4,"measure_mode":"p90"}"#.to_string(),
                r#"{"op":"optimize","workload":"nope"}"#.to_string(),
            ];
            // Repeats exercise the response cache under contention.
            v.push(v[5].clone());
            v.push(v[6].clone());
            v
        };
        // Serial reference on a fresh service.
        let serial_svc = service();
        let expected: Vec<String> = mixed.iter().map(|r| serial_svc.handle(r)).collect();

        let svc = Arc::new(service());
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..4)
                .map(|t| {
                    let svc = Arc::clone(&svc);
                    let mixed = &mixed;
                    let expected = &expected;
                    scope.spawn(move || {
                        // Each thread replays the whole workload, rotated
                        // so threads collide on different ops at once.
                        for i in 0..mixed.len() {
                            let j = (i + t) % mixed.len();
                            let got = svc.handle(&mixed[j]);
                            assert_eq!(
                                got, expected[j],
                                "thread {t} request {j} diverged from serial"
                            );
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
    }

    /// The batch op fans entries across the team and answers each slot
    /// exactly as an individual request would, in input order.
    #[test]
    fn batch_op_matches_individual_requests() {
        let entries = [
            r#"{"op":"optimize","workload":"kmeans:buzz","method":"rs","budget":7,"seed":1}"#,
            r#"{"op":"optimize","workload":"kmeans:buzz","method":"cb-cherrypick","budget":11,"seed":2}"#,
            r#"{"op":"optimize","workload":"xgboost:credit_card","method":"rb","budget":9,"seed":3,"measure_mode":"mean"}"#,
            r#"{"op":"ping"}"#,
            r#"{"op":"optimize","workload":"nope:nope"}"#,
        ];
        let individual_svc = service();
        let expected: Vec<String> =
            entries.iter().map(|r| individual_svc.handle(r)).collect();

        let svc = service();
        let batch = format!(r#"{{"op":"batch","requests":[{}]}}"#, entries.join(","));
        let resp = svc.handle(&batch);
        let v = parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{resp}");
        let responses = v.get("responses").unwrap().as_arr().unwrap();
        assert_eq!(responses.len(), entries.len());
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(
                r.to_string_compact(),
                expected[i],
                "batch slot {i} diverged from the individual request"
            );
        }
        // The error entry failed without poisoning its siblings.
        assert_eq!(responses[4].get("ok").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn batch_validation_errors() {
        let svc = service();
        for bad in [
            r#"{"op":"batch"}"#,
            r#"{"op":"batch","requests":[]}"#,
            r#"{"op":"batch","requests":"x"}"#,
            r#"{"op":"batch","requests":[{"op":"batch","requests":[{"op":"ping"}]}]}"#,
        ] {
            let resp = svc.handle(bad);
            let v = parse(&resp).unwrap();
            if bad.contains("\"requests\":[{") {
                // Outer batch is fine; the nested entry must error.
                let rs = v.get("responses").unwrap().as_arr().unwrap();
                assert_eq!(rs[0].get("ok").unwrap().as_bool(), Some(false), "{resp}");
            } else {
                assert_eq!(v.get("ok").unwrap().as_bool(), Some(false), "{bad} -> {resp}");
            }
        }
    }

    #[test]
    fn adaptive_sizing_tracks_in_flight_requests() {
        let svc = service();
        let s = svc.scheduler();
        assert_eq!(s.in_flight(), 0);
        let cores = default_workers();
        {
            let _a = s.admit();
            assert_eq!(s.in_flight(), 1);
            assert_eq!(s.effective_arm_workers(), cores.clamp(1, MAX_TRIAL_WORKERS));
            let _b = s.admit();
            assert_eq!(s.in_flight(), 2);
            assert_eq!(
                s.effective_arm_workers(),
                (cores / 2).clamp(1, MAX_TRIAL_WORKERS)
            );
        }
        assert_eq!(s.in_flight(), 0, "admission guards must release");
    }

    #[test]
    fn malformed_requests_get_errors_not_panics() {
        let svc = service();
        for bad in [
            "not json",
            r#"{"op":"optimize"}"#,
            r#"{"op":"optimize","workload":"nope:nope"}"#,
            r#"{"op":"optimize","workload":"kmeans:buzz","method":"warp-drive"}"#,
            r#"{"op":"optimize","workload":"kmeans:buzz","target":"speed"}"#,
            r#"{"op":"optimize","workload":"kmeans:buzz","budget":0}"#,
            r#"{"op":"optimize","workload":"kmeans:buzz","trial_workers":9999}"#,
            r#"{"op":"optimize","workload":"kmeans:buzz","trial_workers":"4"}"#,
            r#"{"op":"optimize","workload":"kmeans:buzz","trial_workers":-2}"#,
            r#"{"op":"optimize","workload":"kmeans:buzz","measure_mode":"median"}"#,
            r#"{"op":"optimize","workload":"kmeans:buzz","measure_mode":5}"#,
            r#"{"op":"wat"}"#,
        ] {
            let resp = svc.handle(bad);
            let v = parse(&resp).unwrap();
            assert_eq!(v.get("ok").unwrap().as_bool(), Some(false), "{bad} -> {resp}");
        }
    }

    /// A hello that reaches the dispatcher (not a connection's first
    /// frame) is an error, not a renegotiation.
    #[test]
    fn late_hello_is_an_error() {
        let svc = service();
        for req in [r#"{"op":"hello"}"#, r#"{"op":"hello","codec":"binary"}"#] {
            let resp = svc.handle(req);
            let v = parse(&resp).unwrap();
            assert_eq!(v.get("ok").unwrap().as_bool(), Some(false), "{resp}");
            assert!(resp.contains("first frame"), "{resp}");
        }
    }

    /// `handle_wire` under either codec carries exactly the payload
    /// `handle` produces, framed by that codec — the transports share
    /// one request path.
    #[test]
    fn handle_wire_matches_handle_on_both_codecs() {
        use crate::coordinator::codec::{BINARY, JSON_LINES};
        let svc = service();
        for req in [
            r#"{"op":"ping"}"#,
            r#"{"op":"optimize","workload":"kmeans:buzz","method":"rs","budget":5,"seed":1,"measure_mode":"mean"}"#,
            r#"not json"#,
        ] {
            let expected = svc.handle(req);
            for c in [&JSON_LINES as &'static dyn Codec, &BINARY] {
                let reply = handle_wire(&svc, req.as_bytes(), c, None);
                assert!(!reply.close, "{req} must not close under {}", c.name());
                let mut framed = Vec::new();
                c.encode_frame(&expected, &mut framed);
                assert_eq!(reply.bytes, framed, "{req} diverged under {}", c.name());
            }
        }
        // Non-UTF-8 payloads close silently under both codecs.
        for c in [&JSON_LINES as &'static dyn Codec, &BINARY] {
            let reply = handle_wire(&svc, &[0xff, 0xfe, 0x80], c, None);
            assert!(reply.close && reply.bytes.is_empty(), "codec {}", c.name());
        }
        // The per-codec request counters moved with the traffic (the
        // non-UTF-8 frames are protocol breaks, not requests).
        let v = parse(&svc.handle(r#"{"op":"stats"}"#)).unwrap();
        assert_eq!(v.get("json_requests").and_then(Value::as_usize), Some(3));
        assert_eq!(v.get("binary_requests").and_then(Value::as_usize), Some(3));
    }

    /// `deadline_ms: 0` (already expired) deterministically cancels
    /// after the guaranteed first pull: the partial response carries
    /// `cancelled: "deadline"`, is byte-stable across repeats, never
    /// enters the cache, and moves the cancellation counters.
    #[test]
    fn expired_deadline_returns_a_deterministic_partial_and_skips_the_cache() {
        let svc = service();
        let req = r#"{"op":"optimize","workload":"kmeans:buzz","method":"rs","budget":20,"seed":4,"measure_mode":"mean","trial_workers":1,"deadline_ms":0}"#;
        let first = svc.handle(req);
        let v = parse(&first).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{first}");
        assert_eq!(v.get("cancelled").unwrap().as_str(), Some("deadline"), "{first}");
        assert_eq!(v.get("evals").unwrap().as_usize(), Some(1), "one guaranteed pull");
        // Cache-excluded: the repeat reruns the trial, byte-identically.
        let trials = svc.scheduler().trials_run();
        let second = svc.handle(req);
        assert_eq!(first, second, "cancelled partials must stay deterministic");
        assert_eq!(svc.scheduler().trials_run(), trials + 1, "partial must not be cached");
        assert_eq!(svc.scheduler().cache_hits(), 0);
        assert_eq!(svc.scheduler().cancelled_deadline(), 2);
        assert_eq!(svc.scheduler().cancelled_disconnect(), 0);
        assert!(svc.scheduler().pulls_saved() >= 2, "19 pulls saved per run");
        let stats = parse(&svc.handle(r#"{"op":"stats"}"#)).unwrap();
        assert_eq!(stats.get("cancelled_deadline").and_then(Value::as_usize), Some(2));
        assert!(stats.get("pulls_saved").and_then(Value::as_usize).unwrap() >= 2);
        // The full uncancelled run is the cancelled run's superset: its
        // trace starts with the partial's single point.
        let full = svc.handle(
            r#"{"op":"optimize","workload":"kmeans:buzz","method":"rs","budget":20,"seed":4,"measure_mode":"mean","trial_workers":1,"include_trace":true}"#,
        );
        let fv = parse(&full).unwrap();
        assert!(fv.get("cancelled").is_none(), "{full}");
        assert_eq!(fv.get("evals").unwrap().as_usize(), Some(20));
    }

    /// A server-wide default deadline applies to requests that set
    /// none, and a request's own `deadline_ms` wins over it.
    #[test]
    fn default_deadline_applies_and_requests_override_it() {
        let svc = service().with_default_deadline(Duration::from_millis(0));
        assert_eq!(svc.default_deadline(), None, "zero disables the default");
        let svc = service().with_default_deadline(Duration::from_secs(3600));
        assert_eq!(svc.default_deadline(), Some(Duration::from_secs(3600)));
        // A generous default fires on nothing; responses stay clean.
        let resp = svc.handle(
            r#"{"op":"optimize","workload":"kmeans:buzz","method":"rs","budget":8,"seed":1}"#,
        );
        assert!(parse(&resp).unwrap().get("cancelled").is_none(), "{resp}");
        // A request's own (expired) deadline overrides the generous
        // default.
        let own = svc.handle(
            r#"{"op":"optimize","workload":"kmeans:buzz","method":"rs","budget":8,"seed":1,"trial_workers":1,"deadline_ms":0}"#,
        );
        assert_eq!(
            parse(&own).unwrap().get("cancelled").and_then(Value::as_str),
            Some("deadline"),
            "{own}"
        );
        let stats = parse(&svc.handle(r#"{"op":"stats"}"#)).unwrap();
        assert_eq!(stats.get("default_deadline_ms").and_then(Value::as_usize), Some(3_600_000));
    }

    /// Deadline validation: out-of-range and non-integer values error.
    #[test]
    fn deadline_validation_errors() {
        let svc = service();
        for bad in [
            r#"{"op":"optimize","workload":"kmeans:buzz","deadline_ms":3600001}"#,
            r#"{"op":"optimize","workload":"kmeans:buzz","deadline_ms":"fast"}"#,
            r#"{"op":"optimize","workload":"kmeans:buzz","deadline_ms":-5}"#,
        ] {
            let resp = svc.handle(bad);
            let v = parse(&resp).unwrap();
            assert_eq!(v.get("ok").unwrap().as_bool(), Some(false), "{bad} -> {resp}");
        }
    }

    /// A batch slot with its own deadline runs its own trial — its
    /// cancellation stays in its slot and never becomes the shared
    /// result of an otherwise-identical dedup group.
    #[test]
    fn batch_deadline_slot_is_contained() {
        let det = r#"{"op":"optimize","workload":"kmeans:buzz","method":"rs","budget":10,"seed":1,"measure_mode":"mean","trial_workers":1}"#;
        let det_dl = r#"{"op":"optimize","workload":"kmeans:buzz","method":"rs","budget":10,"seed":1,"measure_mode":"mean","trial_workers":1,"deadline_ms":0}"#;
        let svc = service();
        let batch = format!(r#"{{"op":"batch","requests":[{det},{det_dl},{det}]}}"#);
        let v = parse(&svc.handle(&batch)).unwrap();
        let responses = v.get("responses").unwrap().as_arr().unwrap();
        // Slots 0 and 2 dedup onto one complete trial; slot 1 runs its
        // own and is cancelled.
        assert_eq!(svc.scheduler().trials_run(), 2, "deadline slot must not join the group");
        assert!(responses[0].get("cancelled").is_none(), "{v}");
        assert!(responses[2].get("cancelled").is_none(), "{v}");
        assert_eq!(responses[1].get("cancelled").and_then(Value::as_str), Some("deadline"));
        assert_eq!(responses[0].get("evals").and_then(Value::as_usize), Some(10));
        assert_eq!(responses[1].get("evals").and_then(Value::as_usize), Some(1));
        // The healthy group's complete result went to the cache; the
        // cancelled slot's partial did not displace it.
        let cached = svc.handle(det);
        assert_eq!(parse(&cached).unwrap().get("evals").and_then(Value::as_usize), Some(10));
        assert_eq!(svc.scheduler().cache_hits(), 1);
    }

    /// The byte-level control-plane sniff that routes frames onto the
    /// priority lane: ops that must answer under saturation classify as
    /// high; optimize (and junk) frames never do.
    #[cfg(unix)]
    #[test]
    fn priority_frame_sniff_classifies_control_plane_ops() {
        use super::event_loop::is_priority_frame;
        for fast in [
            r#"{"op":"stats"}"#,
            r#"{"op":"ping"}"#,
            r#"{"op":"clear_cache"}"#,
            r#"{ "op" : "stats" }"#,
            r#"{"op":"list_workloads"}"#,
            r#"{"op":"list_methods"}"#,
            r#"{"op":"hello","codec":"binary"}"#,
        ] {
            assert!(is_priority_frame(fast.as_bytes()), "{fast}");
        }
        for slow in [
            r#"{"op":"optimize","workload":"kmeans:buzz"}"#,
            r#"{"op":"batch","requests":[{"op":"ping"}]}"#,
            r#"{"op":"statsX"}"#,
            r#"{"op":"pingpong"}"#,
            r#"{"op":42}"#,
            r#"{}"#,
            "not json",
            "",
        ] {
            assert!(!is_priority_frame(slow.as_bytes()), "{slow}");
        }
    }

    /// The pre-serialized cached fast path answers byte-identically to
    /// the cold response and still counts hits/misses coherently.
    #[test]
    fn cached_fast_path_is_byte_identical_and_counts_once() {
        let svc = service();
        let req = r#"{"op":"optimize","workload":"kmeans:buzz","method":"rs","budget":6,"seed":9,"measure_mode":"p90"}"#;
        let cold = svc.handle(req);
        let hit = svc.handle(req);
        assert_eq!(cold, hit);
        assert_eq!(svc.scheduler().cache_hits(), 1);
        assert_eq!(svc.scheduler().cache_misses(), 1, "hits + misses = requests served");
    }

    #[test]
    fn tcp_end_to_end() {
        use std::io::{BufRead, BufReader, Write};
        // Every transport answers over a real socket (unavailable ones
        // degrade to the nearest supported backend, so the loop is safe
        // on any platform).
        for transport in [Transport::Epoll, Transport::Poll, Transport::Threaded] {
            let svc = Arc::new(service().with_transport(transport));
            let stop = Arc::new(AtomicBool::new(false));
            let (port, handle) = svc.serve("127.0.0.1:0", stop.clone()).unwrap();
            {
                let mut conn = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
                conn.set_read_timeout(Some(std::time::Duration::from_secs(30))).unwrap();
                conn.write_all(b"{\"op\":\"ping\"}\n").unwrap();
                let mut line = String::new();
                BufReader::new(conn.try_clone().unwrap()).read_line(&mut line).unwrap();
                assert!(line.contains("pong"), "{}: {line}", transport.name());
            }
            stop.store(true, Ordering::Relaxed);
            handle.join().unwrap();
        }
    }

    /// `with_reactors(0)` is adaptive (`min(cores, 4)`), explicit
    /// values are honored, and absurd ones clamp.
    #[test]
    fn reactor_count_is_adaptive_and_clamped() {
        let adaptive = service().reactor_count();
        assert!((1..=4).contains(&adaptive), "{adaptive}");
        assert_eq!(service().with_reactors(0).reactor_count(), adaptive);
        assert_eq!(service().with_reactors(1).reactor_count(), 1);
        assert_eq!(service().with_reactors(9).reactor_count(), 9);
        assert_eq!(service().with_reactors(usize::MAX).reactor_count(), 256);
    }

    /// The stats op surfaces the transport and every effective limit.
    #[test]
    fn stats_reports_transport_fields() {
        let svc = service();
        let v = parse(&svc.handle(r#"{"op":"stats"}"#)).unwrap();
        assert_eq!(v.get("event_loop").unwrap().as_bool(), Some(crate::util::net::supported()));
        assert_eq!(v.get("transport").unwrap().as_str(), Some(Transport::best().name()));
        let fields = [
            "open_connections",
            "idle_connections",
            "loop_wakeups",
            "ready_events",
            "max_conns",
            "max_conns_requested",
            "max_wbuf",
            "max_pending",
            "rlimit_nofile",
            "cache_misses",
            "cache_inserts",
            "json_connections",
            "binary_connections",
            "json_requests",
            "binary_requests",
            "cache_shards",
            "reactors",
            "cancelled_disconnect",
            "cancelled_deadline",
            "pulls_saved",
            "priority_served",
            "default_deadline_ms",
        ];
        for field in fields {
            assert!(v.get(field).and_then(Value::as_usize).is_some(), "missing {field}");
        }
        for field in ["idle_timeout_s", "shutdown_drain_s"] {
            assert!(v.get(field).is_some(), "missing {field}");
        }
        // Per-reactor arrays exist (empty: nothing is serving here).
        for field in ["per_reactor_open", "per_reactor_wakeups"] {
            assert!(v.get(field).and_then(Value::as_arr).is_some(), "missing {field}");
        }

        let off = service().with_event_loop(false);
        assert!(!off.event_loop_enabled());
        let v = parse(&off.handle(r#"{"op":"stats"}"#)).unwrap();
        assert_eq!(v.get("event_loop").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("transport").unwrap().as_str(), Some("threaded"));
        assert_eq!(
            v.get("reactors").and_then(Value::as_usize),
            Some(0),
            "the threaded transport runs no reactors"
        );
    }

    /// Striping invariants: effective stripe count never exceeds the
    /// cap, per-stripe caps sum exactly to the global cap, residency
    /// respects the global cap under churn, and one stripe restores
    /// exact global semantics.
    #[test]
    fn striped_cache_splits_the_cap_and_stays_bounded() {
        let svc = service().with_cache_cap(5).with_cache_shards(3);
        assert_eq!(svc.scheduler().cache_shards(), 3);
        let per_shard: Vec<usize> = svc
            .scheduler
            .cache
            .shards
            .iter()
            .map(|s| s.store.lock().unwrap().cap)
            .collect();
        assert_eq!(per_shard.iter().sum::<usize>(), 5, "{per_shard:?}");
        assert!(per_shard.iter().all(|&c| c >= 1), "{per_shard:?}");

        // More stripes than cap: clamp so every stripe caps at >= 1.
        let tiny = service().with_cache_cap(2).with_cache_shards(64);
        assert_eq!(tiny.scheduler().cache_shards(), 2);

        // Churn 12 distinct keys through cap 5: residency never
        // exceeds the global cap and the counters balance.
        let req = |seed: usize| {
            format!(
                r#"{{"op":"optimize","workload":"kmeans:buzz","target":"cost","method":"rs","budget":6,"seed":{seed},"measure_mode":"mean"}}"#
            )
        };
        for seed in 0..12 {
            svc.handle(&req(seed));
            assert!(svc.scheduler().cached_responses() <= 5);
        }
        let s = svc.scheduler();
        assert_eq!(s.cache_misses(), 12);
        assert_eq!(s.cache_inserts(), 12);
        assert!(s.cache_evictions() <= s.cache_inserts());
        assert_eq!(
            s.cached_responses() as u64,
            s.cache_inserts() - s.cache_evictions(),
            "inserts minus evictions must equal residency"
        );
    }

    /// Builder-set limits land in stats verbatim (modulo the rlimit
    /// clamp on the connection cap).
    #[test]
    fn limits_are_tunable_and_reported() {
        let svc = service()
            .with_max_conns(7)
            .with_idle_timeout(Duration::from_secs(12))
            .with_max_wbuf(2048)
            .with_max_pending(3)
            .with_shutdown_drain(Duration::from_secs(1));
        assert_eq!(svc.limits().max_pending, 3);
        assert_eq!(svc.effective_max_conns(), 7, "small caps are below any sane rlimit");
        let v = parse(&svc.handle(r#"{"op":"stats"}"#)).unwrap();
        assert_eq!(v.get("max_conns").and_then(Value::as_usize), Some(7));
        assert_eq!(v.get("max_conns_requested").and_then(Value::as_usize), Some(7));
        assert_eq!(v.get("max_wbuf").and_then(Value::as_usize), Some(2048));
        assert_eq!(v.get("max_pending").and_then(Value::as_usize), Some(3));
        assert_eq!(v.get("idle_timeout_s").and_then(Value::as_usize), Some(12));
        assert_eq!(v.get("shutdown_drain_s").and_then(Value::as_usize), Some(1));

        // Zero-ish requests clamp up instead of wedging the loop.
        let floor = service().with_max_conns(0).with_max_pending(0).with_max_wbuf(0);
        assert_eq!(floor.limits().max_conns, 1);
        assert_eq!(floor.limits().max_pending, 1);
        assert_eq!(floor.limits().max_wbuf, 1);
    }

    /// An absurd connection-cap request is clamped to the fd rlimit
    /// (minus the reserve) instead of failing at accept time.
    #[cfg(unix)]
    #[test]
    fn effective_max_conns_respects_rlimit() {
        let svc = service().with_max_conns(usize::MAX);
        let effective = svc.effective_max_conns();
        assert!(effective >= 1);
        assert!(
            effective < usize::MAX,
            "RLIMIT_NOFILE is always finite on Unix, so the cap must clamp"
        );
    }

    /// More concurrent connections than connection workers: the bounded
    /// accept loop queues the overflow and still answers everyone.
    #[test]
    fn bounded_conn_pool_serves_more_clients_than_workers() {
        use std::io::{BufRead, BufReader, Write};
        let svc = Arc::new(service().with_conn_workers(2).with_event_loop(false));
        let stop = Arc::new(AtomicBool::new(false));
        let (port, handle) = svc.clone().serve("127.0.0.1:0", stop.clone()).unwrap();
        std::thread::scope(|scope| {
            let joins: Vec<_> = (0..8)
                .map(|i| {
                    scope.spawn(move || {
                        let mut conn =
                            std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
                        let req = format!(
                            "{{\"op\":\"optimize\",\"workload\":\"kmeans:buzz\",\"method\":\"rs\",\"budget\":5,\"seed\":{i}}}\n"
                        );
                        conn.write_all(req.as_bytes()).unwrap();
                        let mut line = String::new();
                        BufReader::new(conn).read_line(&mut line).unwrap();
                        assert!(line.contains("\"ok\":true"), "{line}");
                    })
                })
                .collect();
            for j in joins {
                j.join().unwrap();
            }
        });
        stop.store(true, Ordering::Relaxed);
        handle.join().unwrap();
    }
}
