//! TCP optimization service: the long-running "request path" deployment.
//!
//! Line-delimited JSON over TCP. The server loads the offline dataset and
//! the PJRT artifacts once at startup; each request runs one optimization
//! and returns the recommended deployment. Python is never involved.
//!
//! Request:
//!   {"op": "optimize", "workload": "kmeans:santander", "target": "cost",
//!    "method": "cb-rbfopt", "budget": 33, "seed": 1,
//!    "trial_workers": 3, "measure_mode": "single_draw"}
//!   {"op": "batch", "requests": [{...}, {...}, ...]}
//!   {"op": "list_workloads"}
//!   {"op": "list_methods"}
//!   {"op": "stats"}
//!   {"op": "clear_cache"}
//!   {"op": "ping"}
//!
//! ## Serving architecture
//!
//! All requests flow through one shared [`Scheduler`]:
//!
//! * **One worker team per process.** Compute parallelism (bandit arm
//!   fan-out, batch fan-out) runs on the persistent
//!   [`global_team`](crate::util::threadpool::global_team) — no thread is
//!   spawned per request or per bandit sweep.
//! * **Bounded admission.** `serve` accepts connections into a bounded
//!   queue drained by a fixed pool of connection workers
//!   ([`Service::with_conn_workers`]); when the queue is full the
//!   acceptor stops pulling from the TCP backlog instead of spawning
//!   unbounded threads.
//! * **Adaptive arm workers.** A request that leaves `trial_workers`
//!   unset (or 0) gets `max(1, cores / in-flight requests)` arm workers —
//!   a lone request fans its bandit arms across the machine, a busy
//!   server leans on request-level parallelism instead. Explicit values
//!   are honored as before. Either way results are bit-identical; the
//!   knob only moves latency.
//! * **Cross-request response cache (bounded LRU).** Deterministic-mode
//!   requests (`measure_mode` of `mean`/`p90`) are answered from a cache
//!   keyed by (workload, target, method, budget, seed, measure_mode): a
//!   repeat request returns the byte-identical response with zero new
//!   source measurements. The cache holds at most
//!   [`Service::with_cache_cap`] entries (default [`DEFAULT_CACHE_CAP`])
//!   and evicts least-recently-used, so a long-lived server stays
//!   bounded under adversarial key churn; `{"op":"clear_cache"}` drops
//!   it wholesale. `single_draw` requests are never cached (repeat
//!   evaluations legitimately re-draw).
//! * **Batch op.** `{"op":"batch","requests":[...]}` fans a request list
//!   across the team and returns per-request responses in input order;
//!   a failing entry yields an error object in its slot without
//!   poisoning the rest. Identical *deterministic* entries are
//!   pre-grouped so each distinct key runs exactly one trial (the
//!   duplicates receive copies of the representative's response) —
//!   a guarantee, not the cache race it used to be. Entries executed on
//!   team threads run their own arm fan-out inline — request-level
//!   parallelism already saturates the team, so per-entry arm workers
//!   would only add queue pressure.
//!
//! Response (optimize):
//!   {"ok": true, "config": "gcp/family=e2/...", "value": 0.123,
//!    "evals": 33, "search_expense": 4.56, "regret": 0.01}

use std::collections::{BTreeMap, HashMap};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, TrySendError};
use std::sync::{Arc, Mutex};

use crate::coordinator::experiment::{run_trial, TrialSpec, PREDICTORS};
use crate::coordinator::spec::MAX_TRIAL_WORKERS;
use crate::dataset::objective::MeasureMode;
use crate::dataset::{OfflineDataset, Target};
use crate::optimizers::ALL_OPTIMIZERS;
use crate::surrogate::Backend;
use crate::util::json::{parse, Value};
use crate::util::threadpool::{default_workers, global_team, parallel_map_owned, WorkerTeam};

/// Largest request list one batch op accepts.
pub const MAX_BATCH: usize = 256;

/// Default bound on cached deterministic-mode responses (LRU beyond it).
pub const DEFAULT_CACHE_CAP: usize = 1024;

/// Cache key for deterministic-mode responses. `trial_workers` is
/// deliberately absent: worker counts never change results, so requests
/// differing only in parallelism share one cache entry.
#[derive(Clone, PartialEq, Eq, Hash)]
struct ResponseKey {
    workload: usize,
    target: Target,
    method: String,
    budget: usize,
    seed: u64,
    mode: MeasureMode,
}

/// Bounded LRU store behind the cross-request response cache: a key map
/// carrying each entry's last-use tick plus a tick-ordered index, so a
/// hit is O(log n) and eviction pops the stalest tick. Plain maps (no
/// external LRU crate — this tree builds offline with zero deps).
struct ResponseCache {
    cap: usize,
    tick: u64,
    map: HashMap<ResponseKey, (Value, u64)>,
    order: BTreeMap<u64, ResponseKey>,
}

impl ResponseCache {
    fn new(cap: usize) -> ResponseCache {
        ResponseCache { cap: cap.max(1), tick: 0, map: HashMap::new(), order: BTreeMap::new() }
    }

    /// Look up and mark as most-recently-used.
    fn get(&mut self, key: &ResponseKey) -> Option<Value> {
        self.tick += 1;
        let tick = self.tick;
        let (resp, last) = self.map.get_mut(key)?;
        let stale = std::mem::replace(last, tick);
        let resp = resp.clone();
        self.order.remove(&stale);
        self.order.insert(tick, key.clone());
        Some(resp)
    }

    /// Insert (first writer wins), evicting least-recently-used entries
    /// past the cap. Returns how many entries were evicted.
    fn insert(&mut self, key: ResponseKey, resp: Value) -> usize {
        if self.map.contains_key(&key) {
            // A racing duplicate computed the identical response
            // (deterministic mode), so the existing entry serves.
            return 0;
        }
        let mut evicted = 0;
        while self.map.len() >= self.cap {
            let Some((&stalest, _)) = self.order.iter().next() else { break };
            if let Some(victim) = self.order.remove(&stalest) {
                self.map.remove(&victim);
                evicted += 1;
            }
        }
        self.tick += 1;
        self.order.insert(self.tick, key.clone());
        self.map.insert(key, (resp, self.tick));
        evicted
    }

    fn clear(&mut self) -> usize {
        let n = self.map.len();
        self.map.clear();
        self.order.clear();
        n
    }

    fn len(&self) -> usize {
        self.map.len()
    }
}

/// Process-wide request scheduler: owns the admission count, the
/// adaptive arm-worker sizing, and the cross-request response cache.
/// One per [`Service`]; all connections and batch entries share it.
pub struct Scheduler {
    /// The process compute team all request parallelism lands on.
    team: &'static WorkerTeam,
    in_flight: AtomicUsize,
    cache: Mutex<ResponseCache>,
    cache_hits: AtomicU64,
    cache_evictions: AtomicU64,
    trials_run: AtomicU64,
}

/// RAII in-flight marker for one admitted request.
struct Admission<'a>(&'a Scheduler);

impl Drop for Admission<'_> {
    fn drop(&mut self) {
        self.0.in_flight.fetch_sub(1, Ordering::AcqRel);
    }
}

impl Scheduler {
    fn new(cache_cap: usize) -> Scheduler {
        Scheduler {
            team: global_team(),
            in_flight: AtomicUsize::new(0),
            cache: Mutex::new(ResponseCache::new(cache_cap)),
            cache_hits: AtomicU64::new(0),
            cache_evictions: AtomicU64::new(0),
            trials_run: AtomicU64::new(0),
        }
    }

    /// Admit one request; the returned guard keeps it counted in-flight.
    fn admit(&self) -> Admission<'_> {
        self.in_flight.fetch_add(1, Ordering::AcqRel);
        Admission(self)
    }

    /// Requests currently executing (including batch entries).
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Acquire)
    }

    /// Arm workers for a request that left `trial_workers` unset: divide
    /// the machine across the requests currently in flight.
    pub fn effective_arm_workers(&self) -> usize {
        (default_workers() / self.in_flight().max(1)).clamp(1, MAX_TRIAL_WORKERS)
    }

    /// Worker threads in the process compute team.
    pub fn team_threads(&self) -> usize {
        self.team.threads()
    }

    /// Responses served straight from the cross-request cache so far.
    pub fn cache_hits(&self) -> u64 {
        self.cache_hits.load(Ordering::Relaxed)
    }

    /// Entries evicted from the response cache so far (LRU past the cap).
    pub fn cache_evictions(&self) -> u64 {
        self.cache_evictions.load(Ordering::Relaxed)
    }

    /// Optimization trials actually executed (cache misses + uncacheable).
    pub fn trials_run(&self) -> u64 {
        self.trials_run.load(Ordering::Relaxed)
    }

    /// Deterministic-mode responses currently cached.
    pub fn cached_responses(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Drop every cached response; returns how many were held.
    pub fn clear_cache(&self) -> usize {
        self.cache.lock().unwrap().clear()
    }

    fn cache_lookup(&self, key: &ResponseKey) -> Option<Value> {
        let hit = self.cache.lock().unwrap().get(key);
        if hit.is_some() {
            self.cache_hits.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    fn cache_store(&self, key: ResponseKey, resp: Value) {
        let evicted = self.cache.lock().unwrap().insert(key, resp);
        if evicted > 0 {
            self.cache_evictions.fetch_add(evicted as u64, Ordering::Relaxed);
        }
    }
}

pub struct Service {
    ds: Arc<OfflineDataset>,
    backend: Arc<dyn Backend + Send + Sync>,
    scheduler: Scheduler,
    conn_workers: usize,
}

/// Parsed + validated fields of one optimize request (the single source
/// of request defaults: target `cost`, method `cb-rbfopt`, budget 33,
/// seed 0, adaptive workers, `single_draw`).
struct OptimizeParams {
    workload: usize,
    workload_id: String,
    target: Target,
    method: String,
    budget: usize,
    seed: u64,
    /// 0 = adaptive (sized at execution time from in-flight load).
    trial_workers: usize,
    measure_mode: MeasureMode,
}

impl OptimizeParams {
    /// The response identity: everything that can change the answer.
    /// `trial_workers` is deliberately absent — worker counts never
    /// change results — so it also backs batch dedup at exactly the
    /// response-cache granularity.
    fn key(&self) -> ResponseKey {
        ResponseKey {
            workload: self.workload,
            target: self.target,
            method: self.method.clone(),
            budget: self.budget,
            seed: self.seed,
            mode: self.measure_mode,
        }
    }
}

impl Service {
    pub fn new(ds: Arc<OfflineDataset>, backend: Arc<dyn Backend + Send + Sync>) -> Service {
        Service {
            ds,
            backend,
            scheduler: Scheduler::new(DEFAULT_CACHE_CAP),
            conn_workers: default_workers().clamp(2, 32),
        }
    }

    /// Size the connection-worker pool (the bound on concurrently served
    /// connections; further connections wait in the accept queue).
    pub fn with_conn_workers(mut self, workers: usize) -> Service {
        self.conn_workers = workers.max(1);
        self
    }

    /// Bound the cross-request response cache (entries, min 1): beyond
    /// it the least-recently-used response is evicted. Long-lived
    /// servers stay memory-bounded no matter how many distinct
    /// deterministic keys clients churn through.
    pub fn with_cache_cap(mut self, cap: usize) -> Service {
        self.scheduler.cache.lock().unwrap().cap = cap.max(1);
        self
    }

    /// The shared request scheduler (stats + sizing).
    pub fn scheduler(&self) -> &Scheduler {
        &self.scheduler
    }

    /// Handle one request line; always returns a JSON response line.
    pub fn handle(&self, line: &str) -> String {
        match parse(line)
            .map_err(|e| format!("bad json: {e}"))
            .and_then(|req| self.handle_request(&req, 0))
        {
            Ok(v) => v.to_string_compact(),
            Err(e) => Value::obj(vec![("ok", false.into()), ("error", e.into())])
                .to_string_compact(),
        }
    }

    /// Dispatch one parsed request. `depth` guards against nested batch
    /// ops (a batch entry may not itself be a batch).
    fn handle_request(&self, req: &Value, depth: usize) -> Result<Value, String> {
        let op = req.get("op").and_then(|v| v.as_str()).unwrap_or("optimize");
        match op {
            "ping" => Ok(Value::obj(vec![("ok", true.into()), ("pong", true.into())])),
            "list_workloads" => {
                let names: Vec<Value> =
                    self.ds.workloads.iter().map(|w| Value::str(w.id())).collect();
                Ok(Value::obj(vec![("ok", true.into()), ("workloads", Value::Arr(names))]))
            }
            "list_methods" => {
                let names: Vec<Value> =
                    ALL_OPTIMIZERS.iter().map(|m| Value::str(*m)).collect();
                Ok(Value::obj(vec![("ok", true.into()), ("methods", Value::Arr(names))]))
            }
            "stats" => {
                let s = &self.scheduler;
                Ok(Value::obj(vec![
                    ("ok", true.into()),
                    ("in_flight", s.in_flight().into()),
                    ("trials_run", (s.trials_run() as usize).into()),
                    ("cache_hits", (s.cache_hits() as usize).into()),
                    ("cache_evictions", (s.cache_evictions() as usize).into()),
                    ("cached_responses", s.cached_responses().into()),
                    ("cache_cap", s.cache.lock().unwrap().cap.into()),
                    ("team_threads", s.team_threads().into()),
                    ("conn_workers", self.conn_workers.into()),
                ]))
            }
            "clear_cache" => {
                let cleared = self.scheduler.clear_cache();
                Ok(Value::obj(vec![("ok", true.into()), ("cleared", cleared.into())]))
            }
            "optimize" => self.handle_optimize(req),
            "batch" => {
                if depth > 0 {
                    return Err("batch requests cannot be nested".into());
                }
                let reqs = req
                    .get("requests")
                    .and_then(Value::as_arr)
                    .ok_or("batch needs a 'requests' array")?;
                if reqs.is_empty() {
                    return Err("batch 'requests' is empty".into());
                }
                if reqs.len() > MAX_BATCH {
                    return Err(format!("batch larger than {MAX_BATCH} requests"));
                }
                // Parse optimize entries once up front: the parse feeds
                // both dedup (pre-grouping identical deterministic keys
                // so each distinct key runs exactly one trial — a
                // guarantee, where relying on the response cache alone
                // would let racing duplicates both run) and execution
                // (representatives run from their parsed params, no
                // re-parse).
                let mut plans: Vec<Option<OptimizeParams>> = reqs
                    .iter()
                    .map(|r| match r.get("op").and_then(|v| v.as_str()) {
                        None | Some("optimize") => self.parse_optimize(r).ok(),
                        Some(_) => None,
                    })
                    .collect();
                let mut rep_of: Vec<usize> = Vec::with_capacity(reqs.len());
                let mut first_seen: HashMap<ResponseKey, usize> = HashMap::new();
                for (i, plan) in plans.iter().enumerate() {
                    match plan.as_ref().filter(|p| p.measure_mode.deterministic()) {
                        Some(p) => rep_of.push(*first_seen.entry(p.key()).or_insert(i)),
                        None => rep_of.push(i),
                    }
                }
                // Fan the representative entries across the team; every
                // representative yields a response for its slot (errors
                // become error objects, never poison siblings).
                let uniques: Vec<(usize, Option<OptimizeParams>)> = (0..reqs.len())
                    .filter(|&i| rep_of[i] == i)
                    .map(|i| (i, plans[i].take()))
                    .collect();
                let slot_of: HashMap<usize, usize> =
                    uniques.iter().enumerate().map(|(s, &(i, _))| (i, s)).collect();
                let unique_responses =
                    parallel_map_owned(uniques, default_workers(), |(i, plan)| {
                        // Contain panics per entry: one panicking trial
                        // must produce an error object in its own slot,
                        // not collapse the sibling responses.
                        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| match plan {
                            Some(p) => Ok(self.run_optimize(p)),
                            None => self.handle_request(&reqs[i], depth + 1),
                        }))
                        .unwrap_or_else(|_| Err("internal error handling request".into()))
                        .unwrap_or_else(|e| {
                            Value::obj(vec![("ok", false.into()), ("error", e.into())])
                        })
                    });
                let responses: Vec<Value> = rep_of
                    .iter()
                    .map(|rep| unique_responses[slot_of[rep]].clone())
                    .collect();
                Ok(Value::obj(vec![
                    ("ok", true.into()),
                    ("responses", Value::Arr(responses)),
                ]))
            }
            other => Err(format!("unknown op '{other}'")),
        }
    }

    /// Parse + validate an optimize request (also the batch-dedup
    /// front-end: validation must happen here so entries that would
    /// error never collapse onto a healthy representative).
    fn parse_optimize(&self, req: &Value) -> Result<OptimizeParams, String> {
        let workload_id = req
            .get("workload")
            .and_then(|v| v.as_str())
            .ok_or("missing 'workload'")?;
        let workload = self
            .ds
            .workload_index(workload_id)
            .ok_or_else(|| format!("unknown workload '{workload_id}'"))?;
        let target = Target::parse(
            req.get("target").and_then(|v| v.as_str()).unwrap_or("cost"),
        )
        .ok_or("target must be 'time' or 'cost'")?;
        let method = req
            .get("method")
            .and_then(|v| v.as_str())
            .unwrap_or("cb-rbfopt")
            .to_string();
        // Validate here: `run_trial` panics on unknown methods, and a
        // panic would kill a pooled connection worker.
        if !ALL_OPTIMIZERS.contains(&method.as_str()) && !PREDICTORS.contains(&method.as_str()) {
            return Err(format!("unknown method '{method}'"));
        }
        let budget = req.get("budget").and_then(|v| v.as_usize()).unwrap_or(33);
        let seed = req.get("seed").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
        if budget == 0 || budget > 10_000 {
            return Err("budget out of range".into());
        }
        // 0 (or absent) = adaptive: sized at execution, after admission.
        let trial_workers = match req.get("trial_workers") {
            None => 0,
            Some(v) => v
                .as_usize()
                .ok_or("trial_workers must be a non-negative integer")?,
        };
        if trial_workers > MAX_TRIAL_WORKERS {
            return Err(format!(
                "trial_workers must be in 0..={MAX_TRIAL_WORKERS} (0 = adaptive)"
            ));
        }
        let measure_mode = match req.get("measure_mode") {
            None => MeasureMode::SingleDraw,
            Some(v) => {
                let s = v.as_str().ok_or("measure_mode must be a string")?;
                MeasureMode::parse(s).ok_or_else(|| {
                    format!("bad measure_mode '{s}' (single_draw | mean | p90)")
                })?
            }
        };
        Ok(OptimizeParams {
            workload,
            workload_id: workload_id.to_string(),
            target,
            method,
            budget,
            seed,
            trial_workers,
            measure_mode,
        })
    }

    fn handle_optimize(&self, req: &Value) -> Result<Value, String> {
        let p = self.parse_optimize(req)?;
        Ok(self.run_optimize(p))
    }

    /// Execute a parsed + validated optimize request (infallible past
    /// validation: cache hit or a real trial).
    fn run_optimize(&self, p: OptimizeParams) -> Value {
        // Count this request in-flight from here on: the adaptive sizing
        // below divides the machine by what is actually running.
        let _admission = self.scheduler.admit();

        // Deterministic modes answer repeats from the response cache —
        // zero new measurements, byte-identical response.
        let key = p.key();
        if p.measure_mode.deterministic() {
            if let Some(hit) = self.scheduler.cache_lookup(&key) {
                return hit;
            }
        }

        let trial_workers = if p.trial_workers == 0 {
            self.scheduler.effective_arm_workers()
        } else {
            p.trial_workers
        };
        let spec = TrialSpec {
            method: p.method,
            workload: p.workload,
            target: p.target,
            budget: p.budget,
            seed: p.seed,
            trial_workers,
            measure_mode: p.measure_mode,
        };
        let r = run_trial(&self.ds, self.backend.as_ref(), &spec);
        self.scheduler.trials_run.fetch_add(1, Ordering::Relaxed);
        let resp = Value::obj(vec![
            ("ok", true.into()),
            ("workload", p.workload_id.into()),
            ("target", p.target.name().into()),
            ("method", spec.method.as_str().into()),
            ("value", r.chosen_value.into()),
            ("regret", r.regret.into()),
            ("evals", r.evals.into()),
            ("search_expense", r.search_expense.into()),
        ]);
        if p.measure_mode.deterministic() {
            self.scheduler.cache_store(key, resp.clone());
        }
        resp
    }

    /// Serve until `stop` is set. Returns the bound local port.
    ///
    /// Bounded accept loop: connections are queued (capacity 2× the
    /// connection-worker pool) and served by a fixed pool of persistent
    /// connection workers; when the queue is full the acceptor simply
    /// stops draining the TCP backlog — admission control instead of a
    /// thread per connection.
    pub fn serve(
        self: Arc<Self>,
        addr: &str,
        stop: Arc<AtomicBool>,
    ) -> std::io::Result<(u16, std::thread::JoinHandle<()>)> {
        let listener = TcpListener::bind(addr)?;
        let port = listener.local_addr()?.port();
        listener.set_nonblocking(true)?;
        let svc = self;
        let handle = std::thread::spawn(move || {
            let n_workers = svc.conn_workers.max(1);
            let (tx, rx) = sync_channel::<TcpStream>(2 * n_workers);
            let rx = Arc::new(Mutex::new(rx));
            let workers: Vec<_> = (0..n_workers)
                .map(|_| {
                    let rx = Arc::clone(&rx);
                    let svc = svc.clone();
                    std::thread::spawn(move || loop {
                        // Guard is a temporary: held while popping only.
                        let conn = rx.lock().unwrap().recv();
                        match conn {
                            Ok(stream) => {
                                let _ = handle_conn(&svc, stream);
                            }
                            Err(_) => break, // acceptor gone: shutdown
                        }
                    })
                })
                .collect();

            while !stop.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let mut pending = Some(stream);
                        while let Some(s) = pending.take() {
                            match tx.try_send(s) {
                                Ok(()) => {}
                                Err(TrySendError::Full(s)) => {
                                    if stop.load(Ordering::Relaxed) {
                                        break; // shed on shutdown
                                    }
                                    std::thread::sleep(std::time::Duration::from_millis(5));
                                    pending = Some(s);
                                }
                                Err(TrySendError::Disconnected(_)) => break,
                            }
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(20));
                    }
                    Err(_) => break,
                }
            }
            drop(tx); // close the queue: workers drain and exit
            for w in workers {
                let _ = w.join();
            }
        });
        Ok((port, handle))
    }
}

fn handle_conn(svc: &Service, stream: TcpStream) -> std::io::Result<()> {
    stream.set_read_timeout(Some(std::time::Duration::from_secs(300)))?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        // Connection workers are a fixed pool: a panic escaping here
        // would permanently shrink it, so any unexpected panic in the
        // request path degrades to an error response instead.
        let resp = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| svc.handle(&line)))
            .unwrap_or_else(|_| {
                Value::obj(vec![
                    ("ok", false.into()),
                    ("error", "internal error handling request".into()),
                ])
                .to_string_compact()
            });
        writer.write_all(resp.as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::surrogate::NativeBackend;

    fn service() -> Service {
        let ds = Arc::new(OfflineDataset::generate(60, 3));
        Service::new(ds, Arc::new(NativeBackend))
    }

    #[test]
    fn ping_and_lists() {
        let svc = service();
        assert!(svc.handle(r#"{"op":"ping"}"#).contains("pong"));
        let w = svc.handle(r#"{"op":"list_workloads"}"#);
        assert!(w.contains("kmeans:santander"), "{w}");
        let m = svc.handle(r#"{"op":"list_methods"}"#);
        assert!(m.contains("cb-rbfopt"), "{m}");
        let s = svc.handle(r#"{"op":"stats"}"#);
        assert!(s.contains("team_threads"), "{s}");
    }

    #[test]
    fn optimize_request_roundtrip() {
        let svc = service();
        let resp = svc.handle(
            r#"{"op":"optimize","workload":"xgboost:credit_card","target":"cost","method":"rs","budget":11,"seed":3}"#,
        );
        let v = parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{resp}");
        assert_eq!(v.get("evals").unwrap().as_usize(), Some(11));
        assert!(v.get("value").unwrap().as_f64().unwrap() > 0.0);
    }

    /// `trial_workers` changes request latency, never the answer — and
    /// leaving it unset (adaptive sizing) answers identically too.
    #[test]
    fn parallel_optimize_requests_match_sequential() {
        let svc = service();
        let req = |workers: &str| {
            format!(
                r#"{{"op":"optimize","workload":"kmeans:buzz","target":"cost","method":"cb-rbfopt","budget":22,"seed":5{workers}}}"#
            )
        };
        let seq = svc.handle(&req(r#","trial_workers":1"#));
        let par = svc.handle(&req(r#","trial_workers":4"#));
        let adaptive = svc.handle(&req(""));
        let auto = svc.handle(&req(r#","trial_workers":0"#));
        assert!(seq.contains("\"ok\":true") || seq.contains("\"ok\": true"), "{seq}");
        assert_eq!(seq, par, "trial_workers changed the response");
        assert_eq!(seq, adaptive, "adaptive sizing changed the response");
        assert_eq!(seq, auto, "trial_workers=0 changed the response");
    }

    #[test]
    fn mean_mode_requests_run_memoized() {
        let svc = service();
        let resp = svc.handle(
            r#"{"op":"optimize","workload":"kmeans:buzz","target":"cost","method":"cherrypick-x1","budget":95,"seed":2,"measure_mode":"mean"}"#,
        );
        let v = parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{resp}");
        assert_eq!(v.get("evals").unwrap().as_usize(), Some(95));
    }

    /// The cross-request cache: a repeated deterministic-mode request is
    /// answered byte-identically with zero new source measurements; a
    /// `single_draw` request is never cached.
    #[test]
    fn repeated_deterministic_request_is_served_from_cache() {
        let svc = service();
        let req = r#"{"op":"optimize","workload":"kmeans:buzz","target":"cost","method":"rs","budget":14,"seed":7,"measure_mode":"mean"}"#;
        let first = svc.handle(req);
        assert!(first.contains("\"ok\":true"), "{first}");
        assert_eq!(svc.scheduler().cache_hits(), 0);
        let trials_before = svc.scheduler().trials_run();
        let reads_before = svc.ds.measurement_reads();
        let second = svc.handle(req);
        assert_eq!(first, second, "cached response must be byte-identical");
        assert_eq!(svc.scheduler().cache_hits(), 1, "second request must hit the cache");
        assert_eq!(svc.scheduler().trials_run(), trials_before, "no new trial");
        assert_eq!(
            svc.ds.measurement_reads(),
            reads_before,
            "cached response performed source measurements"
        );
        // Same key fields but a different seed is a different entry.
        let other = svc.handle(
            r#"{"op":"optimize","workload":"kmeans:buzz","target":"cost","method":"rs","budget":14,"seed":8,"measure_mode":"mean"}"#,
        );
        assert!(other.contains("\"ok\":true"));
        assert_eq!(svc.scheduler().cache_hits(), 1);
        // SingleDraw is uncacheable: repeating it runs a fresh trial.
        let sd = r#"{"op":"optimize","workload":"kmeans:buzz","target":"cost","method":"rs","budget":5,"seed":7}"#;
        let a = svc.handle(sd);
        let trials_mid = svc.scheduler().trials_run();
        let b = svc.handle(sd);
        assert_eq!(a, b, "SingleDraw is still deterministic per spec");
        assert_eq!(svc.scheduler().trials_run(), trials_mid + 1, "SingleDraw reruns");
        assert_eq!(svc.scheduler().cache_hits(), 1);
    }

    /// The LRU cap: the cache never exceeds it, evicts the stalest key,
    /// and a hit refreshes recency (so the hot key survives churn).
    #[test]
    fn response_cache_evicts_least_recently_used_at_cap() {
        let svc = service().with_cache_cap(2);
        let req = |seed: usize| {
            format!(
                r#"{{"op":"optimize","workload":"kmeans:buzz","target":"cost","method":"rs","budget":6,"seed":{seed},"measure_mode":"mean"}}"#
            )
        };
        svc.handle(&req(1)); // cache: [1]
        svc.handle(&req(2)); // cache: [1, 2]
        assert_eq!(svc.scheduler().cached_responses(), 2);
        assert_eq!(svc.scheduler().cache_evictions(), 0);
        // Touch 1 so 2 becomes the LRU victim, then insert 3.
        svc.handle(&req(1));
        assert_eq!(svc.scheduler().cache_hits(), 1);
        svc.handle(&req(3)); // evicts 2 -> cache: [1, 3]
        assert_eq!(svc.scheduler().cached_responses(), 2, "cap must hold");
        assert_eq!(svc.scheduler().cache_evictions(), 1);
        // 1 and 3 still hit; 2 reruns the trial.
        let trials = svc.scheduler().trials_run();
        svc.handle(&req(1));
        svc.handle(&req(3));
        assert_eq!(svc.scheduler().trials_run(), trials, "1 and 3 must still be cached");
        svc.handle(&req(2));
        assert_eq!(svc.scheduler().trials_run(), trials + 1, "2 was evicted and reruns");
        // The stats op reports the new counters.
        let stats = svc.handle(r#"{"op":"stats"}"#);
        let v = parse(&stats).unwrap();
        assert_eq!(v.get("cache_cap").unwrap().as_usize(), Some(2), "{stats}");
        assert!(v.get("cache_evictions").unwrap().as_usize().unwrap() >= 1, "{stats}");
    }

    /// `clear_cache` drops every cached response (reporting the count)
    /// and subsequent repeats rerun their trials.
    #[test]
    fn clear_cache_op_empties_the_response_cache() {
        let svc = service();
        let req = r#"{"op":"optimize","workload":"kmeans:buzz","target":"cost","method":"rs","budget":6,"seed":1,"measure_mode":"mean"}"#;
        svc.handle(req);
        assert_eq!(svc.scheduler().cached_responses(), 1);
        let resp = svc.handle(r#"{"op":"clear_cache"}"#);
        let v = parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{resp}");
        assert_eq!(v.get("cleared").unwrap().as_usize(), Some(1), "{resp}");
        assert_eq!(svc.scheduler().cached_responses(), 0);
        let trials = svc.scheduler().trials_run();
        svc.handle(req);
        assert_eq!(svc.scheduler().trials_run(), trials + 1, "cleared key must rerun");
        // Clearing an empty cache is a no-op reporting 0... after the
        // rerun repopulated one entry.
        let again = svc.handle(r#"{"op":"clear_cache"}"#);
        assert_eq!(parse(&again).unwrap().get("cleared").unwrap().as_usize(), Some(1));
    }

    /// Identical deterministic entries inside one batch run exactly one
    /// trial (pre-grouped, not cache-raced) — including entries that are
    /// only *semantically* identical (different `trial_workers`, key
    /// order, or number spelling); `single_draw` duplicates still run
    /// per slot.
    #[test]
    fn batch_dedups_identical_deterministic_entries() {
        let det = r#"{"op":"optimize","workload":"kmeans:buzz","method":"rs","budget":7,"seed":1,"measure_mode":"mean"}"#;
        // Same response key as `det`: worker count is not part of the
        // response identity, and the textual shape differs.
        let det_tw = r#"{"op":"optimize","method":"rs","workload":"kmeans:buzz","budget":7,"seed":1.0,"measure_mode":"mean","trial_workers":2}"#;
        let sd = r#"{"op":"optimize","workload":"kmeans:buzz","method":"rs","budget":7,"seed":1}"#;
        let svc = service();
        let batch =
            format!(r#"{{"op":"batch","requests":[{det},{det},{sd},{det_tw},{sd}]}}"#);
        let resp = svc.handle(&batch);
        let v = parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{resp}");
        let responses = v.get("responses").unwrap().as_arr().unwrap();
        assert_eq!(responses.len(), 5);
        // 1 trial for the three semantically-equal deterministic slots +
        // 2 for the single_draw slots.
        assert_eq!(svc.scheduler().trials_run(), 3, "deterministic dup must run once");
        for (i, j) in [(0usize, 1usize), (0, 3)] {
            assert_eq!(
                responses[i].to_string_compact(),
                responses[j].to_string_compact(),
                "deduped slots must carry the representative's response"
            );
        }
        // Parity with individual requests on a fresh service.
        let fresh = service();
        assert_eq!(responses[0].to_string_compact(), fresh.handle(det));
        assert_eq!(responses[2].to_string_compact(), fresh.handle(sd));
        // An entry that would error (invalid trial_workers) never
        // collapses onto a healthy representative.
        let bad_tw = r#"{"op":"optimize","workload":"kmeans:buzz","method":"rs","budget":7,"seed":1,"measure_mode":"mean","trial_workers":9999}"#;
        let batch2 = format!(r#"{{"op":"batch","requests":[{det},{bad_tw}]}}"#);
        let v2 = parse(&svc.handle(&batch2)).unwrap();
        let r2 = v2.get("responses").unwrap().as_arr().unwrap();
        assert_eq!(r2[0].get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(r2[1].get("ok").unwrap().as_bool(), Some(false), "invalid entry must error");
    }

    /// N client threads hammering one Service with a mixed op workload
    /// get responses byte-identical to serial execution on a fresh
    /// service.
    #[test]
    fn concurrent_mixed_ops_match_serial_execution() {
        let mixed: Vec<String> = {
            let mut v = vec![
                r#"{"op":"ping"}"#.to_string(),
                r#"{"op":"list_workloads"}"#.to_string(),
                r#"{"op":"list_methods"}"#.to_string(),
                r#"{"op":"optimize","workload":"kmeans:buzz","method":"rs","budget":9,"seed":1}"#.to_string(),
                r#"{"op":"optimize","workload":"kmeans:buzz","method":"cb-rbfopt","budget":11,"seed":2,"trial_workers":2}"#.to_string(),
                r#"{"op":"optimize","workload":"xgboost:credit_card","method":"rb","budget":12,"seed":3,"measure_mode":"mean"}"#.to_string(),
                r#"{"op":"optimize","workload":"kmeans:buzz","method":"cherrypick-x3","budget":10,"seed":4,"measure_mode":"p90"}"#.to_string(),
                r#"{"op":"optimize","workload":"nope"}"#.to_string(),
            ];
            // Repeats exercise the response cache under contention.
            v.push(v[5].clone());
            v.push(v[6].clone());
            v
        };
        // Serial reference on a fresh service.
        let serial_svc = service();
        let expected: Vec<String> = mixed.iter().map(|r| serial_svc.handle(r)).collect();

        let svc = Arc::new(service());
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..4)
                .map(|t| {
                    let svc = Arc::clone(&svc);
                    let mixed = &mixed;
                    let expected = &expected;
                    scope.spawn(move || {
                        // Each thread replays the whole workload, rotated
                        // so threads collide on different ops at once.
                        for i in 0..mixed.len() {
                            let j = (i + t) % mixed.len();
                            let got = svc.handle(&mixed[j]);
                            assert_eq!(
                                got, expected[j],
                                "thread {t} request {j} diverged from serial"
                            );
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
    }

    /// The batch op fans entries across the team and answers each slot
    /// exactly as an individual request would, in input order.
    #[test]
    fn batch_op_matches_individual_requests() {
        let entries = [
            r#"{"op":"optimize","workload":"kmeans:buzz","method":"rs","budget":7,"seed":1}"#,
            r#"{"op":"optimize","workload":"kmeans:buzz","method":"cb-cherrypick","budget":11,"seed":2}"#,
            r#"{"op":"optimize","workload":"xgboost:credit_card","method":"rb","budget":9,"seed":3,"measure_mode":"mean"}"#,
            r#"{"op":"ping"}"#,
            r#"{"op":"optimize","workload":"nope:nope"}"#,
        ];
        let individual_svc = service();
        let expected: Vec<String> =
            entries.iter().map(|r| individual_svc.handle(r)).collect();

        let svc = service();
        let batch = format!(r#"{{"op":"batch","requests":[{}]}}"#, entries.join(","));
        let resp = svc.handle(&batch);
        let v = parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{resp}");
        let responses = v.get("responses").unwrap().as_arr().unwrap();
        assert_eq!(responses.len(), entries.len());
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(
                r.to_string_compact(),
                expected[i],
                "batch slot {i} diverged from the individual request"
            );
        }
        // The error entry failed without poisoning its siblings.
        assert_eq!(responses[4].get("ok").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn batch_validation_errors() {
        let svc = service();
        for bad in [
            r#"{"op":"batch"}"#,
            r#"{"op":"batch","requests":[]}"#,
            r#"{"op":"batch","requests":"x"}"#,
            r#"{"op":"batch","requests":[{"op":"batch","requests":[{"op":"ping"}]}]}"#,
        ] {
            let resp = svc.handle(bad);
            let v = parse(&resp).unwrap();
            if bad.contains("\"requests\":[{") {
                // Outer batch is fine; the nested entry must error.
                let rs = v.get("responses").unwrap().as_arr().unwrap();
                assert_eq!(rs[0].get("ok").unwrap().as_bool(), Some(false), "{resp}");
            } else {
                assert_eq!(v.get("ok").unwrap().as_bool(), Some(false), "{bad} -> {resp}");
            }
        }
    }

    #[test]
    fn adaptive_sizing_tracks_in_flight_requests() {
        let svc = service();
        let s = svc.scheduler();
        assert_eq!(s.in_flight(), 0);
        let cores = default_workers();
        {
            let _a = s.admit();
            assert_eq!(s.in_flight(), 1);
            assert_eq!(s.effective_arm_workers(), cores.clamp(1, MAX_TRIAL_WORKERS));
            let _b = s.admit();
            assert_eq!(s.in_flight(), 2);
            assert_eq!(
                s.effective_arm_workers(),
                (cores / 2).clamp(1, MAX_TRIAL_WORKERS)
            );
        }
        assert_eq!(s.in_flight(), 0, "admission guards must release");
    }

    #[test]
    fn malformed_requests_get_errors_not_panics() {
        let svc = service();
        for bad in [
            "not json",
            r#"{"op":"optimize"}"#,
            r#"{"op":"optimize","workload":"nope:nope"}"#,
            r#"{"op":"optimize","workload":"kmeans:buzz","method":"warp-drive"}"#,
            r#"{"op":"optimize","workload":"kmeans:buzz","target":"speed"}"#,
            r#"{"op":"optimize","workload":"kmeans:buzz","budget":0}"#,
            r#"{"op":"optimize","workload":"kmeans:buzz","trial_workers":9999}"#,
            r#"{"op":"optimize","workload":"kmeans:buzz","trial_workers":"4"}"#,
            r#"{"op":"optimize","workload":"kmeans:buzz","trial_workers":-2}"#,
            r#"{"op":"optimize","workload":"kmeans:buzz","measure_mode":"median"}"#,
            r#"{"op":"optimize","workload":"kmeans:buzz","measure_mode":5}"#,
            r#"{"op":"wat"}"#,
        ] {
            let resp = svc.handle(bad);
            let v = parse(&resp).unwrap();
            assert_eq!(v.get("ok").unwrap().as_bool(), Some(false), "{bad} -> {resp}");
        }
    }

    #[test]
    fn tcp_end_to_end() {
        use std::io::{BufRead, BufReader, Write};
        let svc = Arc::new(service());
        let stop = Arc::new(AtomicBool::new(false));
        let (port, handle) = svc.serve("127.0.0.1:0", stop.clone()).unwrap();
        {
            let mut conn = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
            conn.write_all(b"{\"op\":\"ping\"}\n").unwrap();
            let mut line = String::new();
            BufReader::new(conn.try_clone().unwrap()).read_line(&mut line).unwrap();
            assert!(line.contains("pong"), "{line}");
        }
        stop.store(true, Ordering::Relaxed);
        handle.join().unwrap();
    }

    /// More concurrent connections than connection workers: the bounded
    /// accept loop queues the overflow and still answers everyone.
    #[test]
    fn bounded_conn_pool_serves_more_clients_than_workers() {
        use std::io::{BufRead, BufReader, Write};
        let svc = Arc::new(service().with_conn_workers(2));
        let stop = Arc::new(AtomicBool::new(false));
        let (port, handle) = svc.clone().serve("127.0.0.1:0", stop.clone()).unwrap();
        std::thread::scope(|scope| {
            let joins: Vec<_> = (0..8)
                .map(|i| {
                    scope.spawn(move || {
                        let mut conn =
                            std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
                        let req = format!(
                            "{{\"op\":\"optimize\",\"workload\":\"kmeans:buzz\",\"method\":\"rs\",\"budget\":5,\"seed\":{i}}}\n"
                        );
                        conn.write_all(req.as_bytes()).unwrap();
                        let mut line = String::new();
                        BufReader::new(conn).read_line(&mut line).unwrap();
                        assert!(line.contains("\"ok\":true"), "{line}");
                    })
                })
                .collect();
            for j in joins {
                j.join().unwrap();
            }
        });
        stop.store(true, Ordering::Relaxed);
        handle.join().unwrap();
    }
}
