//! PJRT runtime: load and execute the AOT artifacts from the L3 hot path.
//!
//! `make artifacts` (python, build-time only) lowers the L2 JAX surrogate
//! graphs — with the L1 Pallas Gram kernels inlined — to HLO *text*; this
//! module loads the text with `HloModuleProto::from_text_file`, compiles
//! it once on the PJRT CPU client, and executes it for every BO iteration.
//! Python never runs at request time.
//!
//! [`ArtifactBackend`] implements [`surrogate::Backend`], so every
//! BO-family optimizer transparently runs its surrogate math through XLA.
//! Inputs are padded/masked to the fixed AOT shapes (see
//! `python/compile/model.py`); observation sets larger than `n_max` fall
//! back to the native backend (cannot happen with the paper's budgets,
//! but the seam is safe). The artifact path keeps the default full-refit
//! `gp_session` (the AOT graph is a fixed-shape one-shot fit); the
//! incremental-Cholesky session belongs to the native backend.
//!
//! PJRT execution itself requires the `xla` crate and is compiled only
//! with the `pjrt` cargo feature — the default offline build ships a
//! stub whose `load` fails cleanly into the native fallback.

pub mod artifacts;

pub use artifacts::ArtifactBackend;

/// Default artifact directory, relative to the repo root.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";

/// Resolve the artifact directory: explicit argument, else the
/// `MULTICLOUD_ARTIFACTS` environment variable, else ./artifacts.
pub fn artifact_dir(explicit: Option<&str>) -> String {
    if let Some(d) = explicit {
        return d.to_string();
    }
    std::env::var("MULTICLOUD_ARTIFACTS").unwrap_or_else(|_| DEFAULT_ARTIFACT_DIR.to_string())
}
