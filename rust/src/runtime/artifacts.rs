//! Artifact loading, buffer marshalling and the PJRT-backed surrogate
//! backend.
//!
//! The manifest parser and the [`ArtifactBackend`] type are always
//! compiled; the actual PJRT execution path needs the `xla` crate and
//! lives behind the `pjrt` cargo feature (see README.md §Backends). The
//! default build ships a stub whose `load` fails cleanly, so every caller
//! falls back to [`NativeBackend`] exactly as it would on a machine
//! without compiled artifacts.

use std::path::Path;

use crate::util::json;

/// Errors from the runtime layer, as plain display strings (the tree
/// builds offline with zero external crates, so no error-helper deps).
pub type RuntimeResult<T> = Result<T, String>;

/// Shape contract parsed from artifacts/manifest.json (written by
/// python/compile/aot.py).
#[derive(Clone, Debug, PartialEq)]
pub struct Manifest {
    pub version: usize,
    pub n_max: usize,
    pub m_max: usize,
    pub d: usize,
    pub gp_file: String,
    pub rbf_file: String,
}

impl Manifest {
    pub fn parse(text: &str) -> RuntimeResult<Manifest> {
        let v = json::parse(text).map_err(|e| format!("manifest: {e}"))?;
        let num = |k: &str| {
            v.get(k).and_then(|x| x.as_usize()).ok_or_else(|| format!("manifest: missing {k}"))
        };
        let graphs = v.get("graphs").ok_or("manifest: missing graphs")?;
        let file_of = |g: &str| -> RuntimeResult<String> {
            Ok(graphs
                .get(g)
                .and_then(|x| x.get("file"))
                .and_then(|x| x.as_str())
                .ok_or_else(|| format!("manifest: missing graphs.{g}.file"))?
                .to_string())
        };
        Ok(Manifest {
            version: num("version")?,
            n_max: num("n_max")?,
            m_max: num("m_max")?,
            d: num("d")?,
            gp_file: file_of("gp_matern52")?,
            rbf_file: file_of("rbf_cubic")?,
        })
    }

    /// Read and validate a manifest from an artifact directory.
    pub fn load(dir: &str) -> RuntimeResult<Manifest> {
        let manifest_path = Path::new(dir).join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .map_err(|e| format!("reading {}: {e}", manifest_path.display()))?;
        let manifest = Self::parse(&text)?;
        if manifest.d != crate::domain::ENCODED_DIM {
            return Err(format!(
                "artifact feature width {} != domain encoding {} — re-run `make artifacts`",
                manifest.d,
                crate::domain::ENCODED_DIM
            ));
        }
        Ok(manifest)
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::ArtifactBackend;
#[cfg(feature = "pjrt")]
pub use pjrt::ArtifactBackend;

/// Stub compiled when the `pjrt` feature is off: loading always fails
/// with an actionable message, so callers take their native fallback.
#[cfg(not(feature = "pjrt"))]
mod stub {
    use super::{Manifest, RuntimeResult};
    use crate::linalg::Matrix;
    use crate::surrogate::rbf::RbfPrediction;
    use crate::surrogate::{Backend, NativeBackend, Prediction};

    /// PJRT-backed surrogate backend (stub: built without `pjrt`).
    pub struct ArtifactBackend {
        pub manifest: Manifest,
        fallback: NativeBackend,
    }

    impl ArtifactBackend {
        pub fn load(dir: &str) -> RuntimeResult<ArtifactBackend> {
            Self::load_with_pool(dir, 1)
        }

        pub fn load_with_pool(dir: &str, _pool: usize) -> RuntimeResult<ArtifactBackend> {
            // Validate the manifest anyway so error messages stay honest,
            // then refuse: there is no executor in this build.
            let _ = Manifest::load(dir)?;
            Err("built without the `pjrt` feature — PJRT artifact execution unavailable \
                 (cargo build --features pjrt with the xla crate vendored)"
                .to_string())
        }

        pub fn pool_size(&self) -> usize {
            0
        }
    }

    impl Backend for ArtifactBackend {
        fn gp_fit_predict(&self, x: &Matrix, y: &[f64], cands: &Matrix) -> Prediction {
            self.fallback.gp_fit_predict(x, y, cands)
        }

        fn rbf_fit_predict(
            &self,
            x: &Matrix,
            y: &[f64],
            ridge: f64,
            cands: &Matrix,
        ) -> RbfPrediction {
            self.fallback.rbf_fit_predict(x, y, ridge, cands)
        }
        // gp_session: default full-refit replay (no incremental PJRT path).
    }
}

/// The real PJRT execution path. Requires the `xla` crate; kept feature-
/// gated because this tree must build with zero registry access.
#[cfg(feature = "pjrt")]
mod pjrt {
    use std::path::Path;
    use std::sync::Mutex;

    use super::{Manifest, RuntimeResult};
    use crate::linalg::Matrix;
    use crate::surrogate::gp::{select_ls_downsampled, LML_SUBSET_MAX, LS_GRID};
    use crate::surrogate::rbf::RbfPrediction;
    use crate::surrogate::{standardize, Backend, NativeBackend, Prediction};

    /// GP hyperparameters mirroring the native surrogate defaults.
    const NOISE: f32 = 1e-2;
    const SIGNAL_VAR: f32 = 1.0;
    /// kappa only affects the in-graph neg_lcb output (unused:
    /// acquisitions are recomputed Rust-side from mean/std, identically
    /// for both backends).
    const KAPPA: f32 = 2.0;

    struct Executables {
        gp: xla::PjRtLoadedExecutable,
        rbf: xla::PjRtLoadedExecutable,
    }

    // SAFETY: `PjRtLoadedExecutable` is !Send only because it holds an
    // `Rc<PjRtClientInternal>` (non-atomic refcount) and raw PJRT
    // pointers. We never clone those Rcs and never hand out references:
    // every use — including the eventual drop — happens either on the
    // constructing thread or under the `Mutex` in `ArtifactBackend`, so
    // the refcount is never mutated concurrently. PJRT CPU execution
    // itself is thread-safe.
    unsafe impl Send for Executables {}

    /// PJRT-backed surrogate backend.
    ///
    /// `Sync` via a *pool* of independently-locked (client, executables)
    /// slots: the coordinator runs trials on many threads, and PJRT
    /// wrapper types are not `Sync`, so each slot owns its own PJRT
    /// client and compiled executables and is only ever touched under its
    /// mutex. Submissions pick a free slot (try_lock scan) and fall back
    /// to blocking on their round-robin slot. Pool size 1 reproduces the
    /// fully-serialized behaviour (the §Perf before-case).
    ///
    /// `gp_session` stays on the default full-refit replay: the AOT graph
    /// is a fixed-shape one-shot fit, so there is no incremental
    /// factorization to reuse — the parity tests pin replay == one-shot.
    pub struct ArtifactBackend {
        pub manifest: Manifest,
        pool: Vec<Mutex<Executables>>,
        next: std::sync::atomic::AtomicUsize,
        fallback: NativeBackend,
    }

    fn literal_f32(data: &[f32], dims: &[i64]) -> RuntimeResult<xla::Literal> {
        xla::Literal::vec1(data).reshape(dims).map_err(|e| e.to_string())
    }

    impl ArtifactBackend {
        /// Load + compile both artifacts with a default pool size
        /// (min(cores, 8)).
        pub fn load(dir: &str) -> RuntimeResult<ArtifactBackend> {
            Self::load_with_pool(dir, crate::util::threadpool::default_workers().min(8))
        }

        /// Load + compile both artifacts from a directory, with `pool`
        /// slots for concurrent execution.
        pub fn load_with_pool(dir: &str, pool: usize) -> RuntimeResult<ArtifactBackend> {
            let manifest = Manifest::load(dir)?;
            let read = |f: &str| {
                std::fs::read_to_string(Path::new(dir).join(f)).map_err(|e| e.to_string())
            };
            let gp_text = read(&manifest.gp_file)?;
            let rbf_text = read(&manifest.rbf_file)?;
            let slots = (0..pool.max(1))
                .map(|_| {
                    // One client per slot: executables hold Rc<client>,
                    // and slots are locked independently, so sharing one
                    // client would race its (non-atomic) refcount.
                    let client = xla::PjRtClient::cpu().map_err(|e| e.to_string())?;
                    let compile = |text: &str| -> RuntimeResult<xla::PjRtLoadedExecutable> {
                        let proto =
                            xla::HloModuleProto::parse_and_return_unverified_module(
                                text.as_bytes(),
                            )
                            .map_err(|e| e.to_string())?;
                        let comp = xla::XlaComputation::from_proto(&proto);
                        client.compile(&comp).map_err(|e| e.to_string())
                    };
                    Ok(Mutex::new(Executables {
                        gp: compile(&gp_text)?,
                        rbf: compile(&rbf_text)?,
                    }))
                })
                .collect::<RuntimeResult<Vec<_>>>()?;
            Ok(ArtifactBackend {
                manifest,
                pool: slots,
                next: std::sync::atomic::AtomicUsize::new(0),
                fallback: NativeBackend,
            })
        }

        pub fn pool_size(&self) -> usize {
            self.pool.len()
        }

        /// Acquire a slot: first free one by try_lock scan, else block on
        /// the round-robin slot.
        fn slot(&self) -> std::sync::MutexGuard<'_, Executables> {
            for m in &self.pool {
                if let Ok(g) = m.try_lock() {
                    return g;
                }
            }
            let i =
                self.next.fetch_add(1, std::sync::atomic::Ordering::Relaxed) % self.pool.len();
            self.pool[i].lock().unwrap()
        }

        /// Pad observations/candidates into the fixed AOT buffers.
        #[allow(clippy::type_complexity)]
        fn pack(
            &self,
            x: &Matrix,
            y: &[f64],
            cands: &Matrix,
        ) -> RuntimeResult<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, usize, usize)> {
            let (n_max, m_max, d) = (self.manifest.n_max, self.manifest.m_max, self.manifest.d);
            let n = x.rows;
            let m = cands.rows;
            if n > n_max || m > m_max {
                return Err(format!("{n} observations / {m} candidates exceed AOT shapes"));
            }
            if x.cols != d {
                return Err(format!("encoded width {} != artifact d {d}", x.cols));
            }
            if cands.cols != d {
                return Err(format!("candidate width {} != artifact d {d}", cands.cols));
            }
            let mut xb = vec![0f32; n_max * d];
            for i in 0..n {
                for (j, &v) in x.row(i).iter().enumerate() {
                    xb[i * d + j] = v as f32;
                }
            }
            let mut yb = vec![0f32; n_max];
            for (i, &v) in y.iter().enumerate() {
                yb[i] = v as f32;
            }
            let mut mask = vec![0f32; n_max];
            mask[..n].fill(1.0);
            let mut cb = vec![0f32; m_max * d];
            for i in 0..m {
                for (j, &v) in cands.row(i).iter().enumerate() {
                    cb[i * d + j] = v as f32;
                }
            }
            Ok((xb, yb, mask, cb, n, m))
        }

        /// One GP artifact execution. Returns (mean, std, lml) truncated
        /// to m.
        fn exec_gp(
            &self,
            xb: &[f32],
            yb: &[f32],
            mask: &[f32],
            cb: &[f32],
            hyp: [f32; 5],
            m: usize,
        ) -> RuntimeResult<(Vec<f64>, Vec<f64>, f64)> {
            let (n_max, m_max, d) = (
                self.manifest.n_max as i64,
                self.manifest.m_max as i64,
                self.manifest.d as i64,
            );
            let args = [
                literal_f32(xb, &[n_max, d])?,
                literal_f32(yb, &[n_max])?,
                literal_f32(mask, &[n_max])?,
                literal_f32(cb, &[m_max, d])?,
                literal_f32(&hyp, &[5])?,
            ];
            let exes = self.slot();
            let result = exes
                .gp
                .execute::<xla::Literal>(&args)
                .map_err(|e| e.to_string())?[0][0]
                .to_literal_sync()
                .map_err(|e| e.to_string())?;
            drop(exes);
            let parts = result.to_tuple().map_err(|e| e.to_string())?;
            if parts.len() != 6 {
                return Err(format!("gp artifact returned {} outputs, expected 6", parts.len()));
            }
            let mean: Vec<f32> = parts[0].to_vec().map_err(|e| e.to_string())?;
            let std: Vec<f32> = parts[1].to_vec().map_err(|e| e.to_string())?;
            let lml: Vec<f32> = parts[5].to_vec().map_err(|e| e.to_string())?;
            Ok((
                mean[..m].iter().map(|&v| v as f64).collect(),
                std[..m].iter().map(|&v| v as f64).collect(),
                lml[0] as f64,
            ))
        }
    }

    impl Backend for ArtifactBackend {
        fn gp_fit_predict(&self, x: &Matrix, y: &[f64], cands: &Matrix) -> Prediction {
            if x.rows > self.manifest.n_max || cands.rows > self.manifest.m_max {
                return self.fallback.gp_fit_predict(x, y, cands);
            }
            // Same convention as the native GP: standardize y, grid-search
            // the lengthscale by in-graph log marginal likelihood.
            let (z, ym, ys) = standardize(y);
            let (xb, zb, mask, cb, _n, m) = match self.pack(x, &z, cands) {
                Ok(t) => t,
                Err(_) => return self.fallback.gp_fit_predict(x, y, cands),
            };
            let best_z = z.iter().copied().fold(f64::INFINITY, f64::min) as f32;

            // Past LML_SUBSET_MAX observations the native paths rank the
            // lengthscale grid on a strided subset (downsampled LML) —
            // the ranking is pure Rust, so the artifact path runs the
            // *same* rule and then executes only the winner's graph,
            // keeping lengthscale selection identical across backends
            // (the interchangeability contract) and cutting the ×4 grid
            // cost of large-n artifact fits too.
            let subset_winner = if x.rows > LML_SUBSET_MAX {
                // Rank with the *native* f64 hyperparameters so the
                // subset rule is bit-identical to NativeBackend's (the
                // f32 graph constants round 1e-2 differently).
                let native = crate::surrogate::gp::GpSurrogate::default();
                select_ls_downsampled(x, &z, native.signal_var, native.noise)
            } else {
                None
            };
            let grid: Vec<f64> = match subset_winner {
                Some(li) => vec![LS_GRID[li]],
                None => LS_GRID.to_vec(),
            };

            let mut best: Option<(f64, Vec<f64>, Vec<f64>)> = None;
            for &ls in &grid {
                let hyp = [ls as f32, SIGNAL_VAR, NOISE, best_z, KAPPA];
                match self.exec_gp(&xb, &zb, &mask, &cb, hyp, m) {
                    Ok((mean, std, lml)) => {
                        if best.as_ref().map(|(b, _, _)| lml > *b).unwrap_or(true) {
                            best = Some((lml, mean, std));
                        }
                    }
                    Err(e) => panic!("PJRT gp execution failed: {e}"),
                }
            }
            let (_, mean, std) = best.expect("lengthscale grid non-empty");
            Prediction {
                mean: mean.iter().map(|v| v * ys + ym).collect(),
                std: std.iter().map(|v| v * ys).collect(),
            }
        }

        fn rbf_fit_predict(
            &self,
            x: &Matrix,
            y: &[f64],
            ridge: f64,
            cands: &Matrix,
        ) -> RbfPrediction {
            if x.rows > self.manifest.n_max || cands.rows > self.manifest.m_max {
                return self.fallback.rbf_fit_predict(x, y, ridge, cands);
            }
            let (xb, yb, mask, cb, _n, m) = match self.pack(x, y, cands) {
                Ok(t) => t,
                Err(_) => return self.fallback.rbf_fit_predict(x, y, ridge, cands),
            };
            let (n_max, m_max, d) = (
                self.manifest.n_max as i64,
                self.manifest.m_max as i64,
                self.manifest.d as i64,
            );
            let run = || -> RuntimeResult<RbfPrediction> {
                let args = [
                    literal_f32(&xb, &[n_max, d])?,
                    literal_f32(&yb, &[n_max])?,
                    literal_f32(&mask, &[n_max])?,
                    literal_f32(&cb, &[m_max, d])?,
                    literal_f32(&[ridge as f32], &[1])?,
                ];
                let exes = self.slot();
                let result = exes
                    .rbf
                    .execute::<xla::Literal>(&args)
                    .map_err(|e| e.to_string())?[0][0]
                    .to_literal_sync()
                    .map_err(|e| e.to_string())?;
                drop(exes);
                let (pred_l, mind_l) = result.to_tuple2().map_err(|e| e.to_string())?;
                let pred: Vec<f32> = pred_l.to_vec().map_err(|e| e.to_string())?;
                let mind: Vec<f32> = mind_l.to_vec().map_err(|e| e.to_string())?;
                Ok(RbfPrediction {
                    pred: pred[..m].iter().map(|&v| v as f64).collect(),
                    mindist: mind[..m].iter().map(|&v| v as f64).collect(),
                })
            };
            run().unwrap_or_else(|e| panic!("PJRT rbf execution failed: {e}"))
        }
        // gp_session: default full-refit replay through gp_fit_predict.
    }
}
