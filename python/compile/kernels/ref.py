"""Pure-jnp reference oracles for the L1 Pallas kernels.

These are the correctness ground truth: ``python/tests/test_kernel.py``
sweeps shapes/dtypes with hypothesis and asserts the Pallas kernels in
``matern.py`` match these to numerical tolerance. They are also reused by
the L2 model tests as an independent implementation of the Gram math.
"""

import jax.numpy as jnp


def pairwise_sqdist_ref(a, b):
    """Squared euclidean distance matrix.

    a: [n, d], b: [m, d] -> [n, m] with out[i, j] = ||a_i - b_j||^2.
    Computed with the expanded form (||a||^2 + ||b||^2 - 2 a.b) to match the
    kernel's algorithm, clamped at zero against cancellation.
    """
    a2 = jnp.sum(a * a, axis=1)[:, None]
    b2 = jnp.sum(b * b, axis=1)[None, :]
    d2 = a2 + b2 - 2.0 * (a @ b.T)
    return jnp.maximum(d2, 0.0)


def matern52_ref(a, b, lengthscale, signal_var):
    """Matern-5/2 covariance matrix between row sets a and b.

    k(r) = sv * (1 + u + u^2/3) * exp(-u),   u = sqrt(5) * r / lengthscale
    """
    d2 = pairwise_sqdist_ref(a, b)
    u = jnp.sqrt(5.0 * d2) / lengthscale
    return signal_var * (1.0 + u + u * u / 3.0) * jnp.exp(-u)


def cubic_rbf_ref(a, b):
    """Cubic radial basis phi(r) = r^3 between row sets a and b."""
    d2 = pairwise_sqdist_ref(a, b)
    r = jnp.sqrt(d2)
    return r * d2  # r^3 without a second sqrt
