"""L1 Pallas kernels: block-tiled pairwise covariance matrices.

The per-iteration hot-spot of every BO-family optimizer in the paper is
building the Gram matrix of the observed configurations and the
cross-covariance against the full candidate grid.  These kernels compute

  * ``pairwise_sqdist``  — squared euclidean distances,
  * ``matern52_gram``    — Matern-5/2 covariance (CherryPick / Bilal / RB /
                           CloudBandit's GP component), and
  * ``cubic_rbf_gram``   — cubic RBF basis matrix (RBFOpt-lite component),

tiled over (TILE_N x TILE_M) output blocks.  Each grid step loads one
(TILE_N, d) tile of ``a`` and one (TILE_M, d) tile of ``b`` into VMEM, runs
the contraction on the MXU (``a @ b.T`` at f32), and applies the radial
transform as fused elementwise VPU work on the output tile.

TPU adaptation notes (DESIGN.md §Hardware-Adaptation): the paper targets
CPU clouds, not accelerators, so there is no CUDA idiom to port — but the
kernels are still written the TPU way: BlockSpecs express the HBM->VMEM
schedule, the contraction depth is the (zero-padded) feature dimension, and
all shapes are padded to tile multiples by the wrappers.  ``interpret=True``
is mandatory here: the CPU PJRT plugin cannot execute Mosaic custom-calls,
and the AOT path (python/compile/aot.py) embeds these kernels in the HLO
artifacts executed by the Rust runtime.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Output tile size. 96 (= N_MAX = M_MAX in model.py) is 3 tiles per side.
TILE = 32


def _sqdist_block(a_ref, b_ref):
    """Squared distances between an a-tile and a b-tile (both in VMEM)."""
    a = a_ref[...]
    b = b_ref[...]
    a2 = jnp.sum(a * a, axis=1)[:, None]
    b2 = jnp.sum(b * b, axis=1)[None, :]
    # MXU contraction: (TILE, d) x (d, TILE), accumulated at operand width.
    ab = jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=a.dtype
    )
    return jnp.maximum(a2 + b2 - 2.0 * ab, 0.0)


def _sqdist_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = _sqdist_block(a_ref, b_ref).astype(o_ref.dtype)


def _matern52_kernel(a_ref, b_ref, hyp_ref, o_ref):
    d2 = _sqdist_block(a_ref, b_ref)
    ls = hyp_ref[0, 0]
    sv = hyp_ref[0, 1]
    u = jnp.sqrt(5.0 * d2) / ls
    k = sv * (1.0 + u + u * u / 3.0) * jnp.exp(-u)
    o_ref[...] = k.astype(o_ref.dtype)


def _cubic_kernel(a_ref, b_ref, o_ref):
    d2 = _sqdist_block(a_ref, b_ref)
    o_ref[...] = (jnp.sqrt(d2) * d2).astype(o_ref.dtype)


def _pad_rows(x, mult):
    n = x.shape[0]
    pad = (-n) % mult
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, x.shape[1]), x.dtype)], axis=0)
    return x, n


def _tiled_call(kernel, a, b, extra=None, extra_spec=None):
    """Run a 2-operand (+ optional scalar operand) tile kernel over a grid."""
    a = jnp.asarray(a)
    b = jnp.asarray(b)
    assert a.ndim == 2 and b.ndim == 2 and a.shape[1] == b.shape[1]
    dtype = jnp.result_type(a.dtype, b.dtype)
    a, n = _pad_rows(a.astype(dtype), TILE)
    b, m = _pad_rows(b.astype(dtype), TILE)
    d = a.shape[1]
    grid = (a.shape[0] // TILE, b.shape[0] // TILE)
    in_specs = [
        pl.BlockSpec((TILE, d), lambda i, j: (i, 0)),
        pl.BlockSpec((TILE, d), lambda i, j: (j, 0)),
    ]
    args = [a, b]
    if extra is not None:
        in_specs.append(extra_spec)
        args.append(extra)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((TILE, TILE), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((a.shape[0], b.shape[0]), dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(*args)
    return out[:n, :m]


def pairwise_sqdist(a, b):
    """[n, d] x [m, d] -> [n, m] squared euclidean distances (Pallas)."""
    return _tiled_call(_sqdist_kernel, a, b)


def matern52_gram(a, b, lengthscale, signal_var):
    """[n, d] x [m, d] -> [n, m] Matern-5/2 covariance matrix (Pallas).

    ``lengthscale`` and ``signal_var`` may be python floats or traced
    scalars; they ride along as a (1, 2) operand so a single AOT artifact
    serves every hyperparameter setting.
    """
    a = jnp.asarray(a)
    dtype = jnp.result_type(a.dtype, jnp.asarray(b).dtype)
    hyp = jnp.stack(
        [jnp.asarray(lengthscale, dtype), jnp.asarray(signal_var, dtype)]
    ).reshape(1, 2)
    spec = pl.BlockSpec((1, 2), lambda i, j: (0, 0))
    return _tiled_call(_matern52_kernel, a, b, extra=hyp, extra_spec=spec)


def cubic_rbf_gram(a, b):
    """[n, d] x [m, d] -> [n, m] cubic RBF basis phi(r) = r^3 (Pallas)."""
    return _tiled_call(_cubic_kernel, a, b)


@functools.lru_cache(maxsize=None)
def vmem_tile_bytes(d, dtype_bytes=4):
    """Structural VMEM footprint of one grid step (see DESIGN.md §Perf)."""
    operands = 2 * TILE * d * dtype_bytes
    out = TILE * TILE * dtype_bytes
    return operands + out
