"""L2: fixed-shape surrogate compute graphs, AOT-lowered for the Rust runtime.

Two graphs are exported (see ``aot.py``):

  * ``gp_forward``  — masked Matern-5/2 Gaussian-process posterior over the
    candidate grid + EI / PI / LCB acquisition scores + log marginal
    likelihood.  This is the per-iteration hot path of CherryPick, the
    Bilal et al. schemes, Rising Bandits' component optimizer and
    CloudBandit's GP component.
  * ``rbf_forward`` — cubic-RBF (constant tail) interpolant values over the
    candidate grid + distance-to-nearest-observation, the two ingredients of
    RBFOpt-lite's score.

AOT contract (must match rust/src/runtime/artifacts.rs):
  shapes are fixed at N_MAX observations / M_MAX candidates / D features,
  with 0/1 masks for the live rows.  Padded observations are given unit
  diagonal, zero cross-covariance and zero target, which leaves the
  posterior of live rows exactly unchanged (proved in test_model.py by the
  padding-invariance test).

Everything here must lower to *plain HLO ops*: the standalone XLA runtime
used by the `xla` crate (xla_extension 0.5.1) cannot resolve jaxlib's
LAPACK custom-calls, so Cholesky / triangular solves are implemented as
fori_loop kernels and the normal CDF uses an erf-free polynomial
approximation (Abramowitz & Stegun 7.1.26, |err| < 7.5e-8).
"""

import jax
import jax.numpy as jnp
from jax import lax

from compile.kernels.matern import cubic_rbf_gram, matern52_gram, pairwise_sqdist

# ---------------------------------------------------------------------------
# AOT shape contract. rust/src/domain/encoding.rs mirrors these constants.
N_MAX = 96   # max observations (largest paper budget is 88)
M_MAX = 96   # max candidates (full multi-cloud grid is 88)
D = 20       # flattened one-hot encoding of the hierarchical domain
N_RBF = N_MAX + 1  # RBF saddle system: N_MAX centres + constant tail

JITTER = 1e-5


def norm_cdf(z):
    """Standard normal CDF via A&S 7.1.26 erf approximation (plain HLO)."""
    x = z / jnp.sqrt(2.0).astype(z.dtype)
    sign = jnp.sign(x)
    x = jnp.abs(x)
    t = 1.0 / (1.0 + 0.3275911 * x)
    poly = t * (
        0.254829592
        + t * (-0.284496736 + t * (1.421413741 + t * (-1.453152027 + t * 1.061405429)))
    )
    erf = sign * (1.0 - poly * jnp.exp(-x * x))
    return 0.5 * (1.0 + erf)


def norm_pdf(z):
    return jnp.exp(-0.5 * z * z) / jnp.sqrt(2.0 * jnp.pi).astype(z.dtype)


def cholesky_scan(a):
    """Right-looking Cholesky as a fori_loop (lowers to plain HLO).

    a must be symmetric positive definite. O(n) loop steps, each a rank-1
    vectorized update, so the lowered module is a single while-loop.
    """
    n = a.shape[0]
    idx = jnp.arange(n)

    def body(j, carry):
        a_, l_ = carry
        d = jnp.sqrt(a_[j, j])
        col = jnp.where(idx >= j, a_[:, j] / d, 0.0)
        l_ = l_.at[:, j].set(col)
        a_ = a_ - jnp.outer(col, col)
        return (a_, l_)

    _, l = lax.fori_loop(0, n, body, (a, jnp.zeros_like(a)))
    return l


def solve_lower(l, b):
    """Forward substitution L y = b, b: [n] or [n, m] (plain HLO)."""
    n = l.shape[0]
    y0 = jnp.zeros_like(b)

    def body(i, y):
        yi = (b[i] - l[i, :] @ y) / l[i, i]
        return y.at[i].set(yi)

    return lax.fori_loop(0, n, body, y0)


def solve_upper_t(l, b):
    """Back substitution L^T x = b given lower-triangular L (plain HLO)."""
    n = l.shape[0]
    x0 = jnp.zeros_like(b)

    def body(k, x):
        i = n - 1 - k
        xi = (b[i] - l[:, i] @ x) / l[i, i]
        return x.at[i].set(xi)

    return lax.fori_loop(0, n, body, x0)


def gp_forward(x_obs, y, mask, cands, cmask, hyp):
    """Masked GP posterior + acquisitions over the candidate grid.

    Args (all f32):
      x_obs [N_MAX, D]  observed configurations (padded rows arbitrary)
      y     [N_MAX]     observed losses, standardized by the caller;
                        padded entries must be 0
      mask  [N_MAX]     1.0 for live observations, 0.0 for padding
      cands [M_MAX, D]  candidate configurations
      cmask [M_MAX]     candidate mask (outputs at padded rows are junk;
                        the Rust side masks the argmax)
      hyp   [5]         lengthscale, signal_var, noise_var, best_y, kappa

    Returns tuple:
      mean [M_MAX], std [M_MAX], ei [M_MAX], pi [M_MAX], neg_lcb [M_MAX],
      lml [1]  (log marginal likelihood of the live observations)

    All acquisition outputs are oriented maximize-is-better for a
    minimization objective.
    """
    x_obs, y, mask, cands, hyp = (
        jnp.asarray(v, jnp.float32) for v in (x_obs, y, mask, cands, hyp)
    )
    ls, sv, noise, best_y, kappa = hyp[0], hyp[1], hyp[2], hyp[3], hyp[4]

    y = y * mask
    kxx = matern52_gram(x_obs, x_obs, ls, sv)  # Pallas (L1)
    kxx = kxx * mask[:, None] * mask[None, :]
    # Live diagonal: sv + noise + jitter. Padded diagonal: 1 (unit row).
    diag = mask * (noise + JITTER) + (1.0 - mask)
    kxx = kxx + jnp.diag(diag) - jnp.diag(jnp.diag(kxx) * (1.0 - mask))

    l = cholesky_scan(kxx)
    alpha = solve_upper_t(l, solve_lower(l, y))

    kxc = matern52_gram(x_obs, cands, ls, sv) * mask[:, None]  # [N, M]
    mean = kxc.T @ alpha
    v = solve_lower(l, kxc)  # [N, M]
    var = jnp.maximum(sv - jnp.sum(v * v, axis=0), 1e-12)
    std = jnp.sqrt(var)

    imp = best_y - mean
    z = imp / std
    ei = imp * norm_cdf(z) + std * norm_pdf(z)
    pi = norm_cdf(z)
    neg_lcb = -(mean - kappa * std)

    n_live = jnp.sum(mask)
    quad = -0.5 * jnp.dot(y, alpha)
    # Padded rows have L_ii = 1 -> log 0, so the logdet needs no masking.
    logdet = -jnp.sum(jnp.log(jnp.diagonal(l)))
    lml = quad + logdet - 0.5 * n_live * jnp.log(2.0 * jnp.pi)

    return mean, std, ei, pi, neg_lcb, lml.reshape(1)


def rbf_forward(x_obs, y, mask, cands, cmask, hyp):
    """Cubic-RBF (constant tail) interpolant + min-distance, masked.

    Solves the (N_MAX+1) saddle system
        [ Phi + lam*I   1 ] [c ]   [y]
        [ 1^T           0 ] [d0] = [0]
    restricted to live rows (padded rows are unit rows), via normal
    equations + the scan Cholesky.  The saddle matrix is symmetric
    indefinite with condition ~1e7, so the squared system demands f64:
    the solve path is cast to f64 inside the graph (the AOT interface
    stays f32; XLA CPU executes f64 natively).  Validated against a
    float64 saddle oracle in test_model.py.

    Args: as ``gp_forward``; hyp [1] = lam (ridge on the live diagonal).
    Returns tuple: pred [M_MAX], mindist [M_MAX].
    """
    x_obs, y, mask, cands, hyp = (
        jnp.asarray(v, jnp.float32) for v in (x_obs, y, mask, cands, hyp)
    )
    lam = hyp[0]
    y = y * mask

    f64 = jnp.float64
    mask64 = mask.astype(f64)
    phi = cubic_rbf_gram(x_obs, x_obs).astype(f64)  # Pallas (L1)
    phi = phi * mask64[:, None] * mask64[None, :] + jnp.diag(lam.astype(f64) * mask64)

    a = jnp.zeros((N_RBF, N_RBF), f64)
    a = a.at[:N_MAX, :N_MAX].set(phi)
    a = a.at[:N_MAX, N_MAX].set(mask64)
    a = a.at[N_MAX, :N_MAX].set(mask64)
    # Unit rows for padded centres so the system stays non-singular.
    dead = jnp.concatenate([1.0 - mask64, jnp.zeros((1,), f64)])
    a = a + jnp.diag(dead)

    rhs = jnp.concatenate([y.astype(f64), jnp.zeros((1,), f64)])

    ata = a.T @ a + 1e-10 * jnp.eye(N_RBF, dtype=f64)
    atb = a.T @ rhs
    l = cholesky_scan(ata)
    z = solve_upper_t(l, solve_lower(l, atb))
    coef, d0 = z[:N_MAX] * mask64, z[N_MAX]

    phi_c = cubic_rbf_gram(x_obs, cands).astype(f64) * mask64[:, None]  # [N, M]
    pred = (phi_c.T @ coef + d0).astype(jnp.float32)

    d2 = pairwise_sqdist(x_obs, cands)  # Pallas (L1)
    big = jnp.float32(1e30)
    d2 = jnp.where(mask[:, None] > 0.5, d2, big)
    mindist = jnp.sqrt(jnp.min(d2, axis=0))

    return pred, mindist


def gp_example_args():
    s = jax.ShapeDtypeStruct
    f = jnp.float32
    return (
        s((N_MAX, D), f),
        s((N_MAX,), f),
        s((N_MAX,), f),
        s((M_MAX, D), f),
        s((M_MAX,), f),
        s((5,), f),
    )


def rbf_example_args():
    s = jax.ShapeDtypeStruct
    f = jnp.float32
    return (
        s((N_MAX, D), f),
        s((N_MAX,), f),
        s((N_MAX,), f),
        s((M_MAX, D), f),
        s((M_MAX,), f),
        s((1,), f),
    )
