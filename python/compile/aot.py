"""AOT lowering: JAX (L2, calling the L1 Pallas kernels) -> HLO text.

The interchange format is HLO *text*, NOT a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published `xla` 0.1.6 crate links) rejects
(`proto.id() <= INT_MAX`).  The text parser reassigns ids, so text
round-trips cleanly.  See /opt/xla-example/README.md.

Run from the ``python/`` directory (the Makefile does this):

    python -m compile.aot --out-dir ../artifacts

Python runs exactly once, at build time; the Rust binary only ever touches
``artifacts/``.
"""

import argparse
import json
import os

import jax

# The RBF solve path runs in f64 inside the graph (model.rbf_forward);
# without x64 enabled jax would silently truncate it back to f32.
jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc

from compile import model

MANIFEST_VERSION = 2


def to_hlo_text(fn, example_args) -> str:
    """Lower a jax function to HLO text via stablehlo -> XlaComputation."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# The exported entry signatures drop the unused candidate mask (XLA would
# dead-code-eliminate the parameter anyway, silently shifting the argument
# list under the Rust runtime): (x_obs, y, mask, cands, hyp).
def _gp_entry(x, y, mask, cands, hyp):
    return model.gp_forward(x, y, mask, cands, None, hyp)


def _rbf_entry(x, y, mask, cands, hyp):
    return model.rbf_forward(x, y, mask, cands, None, hyp)


def _drop_cmask(args):
    a = list(args)
    return tuple(a[:4] + a[5:])


GRAPHS = {
    "gp_matern52": (
        _gp_entry,
        lambda: _drop_cmask(model.gp_example_args()),
        ["mean", "std", "ei", "pi", "neg_lcb", "lml"],
        5,  # hyp length
    ),
    "rbf_cubic": (
        _rbf_entry,
        lambda: _drop_cmask(model.rbf_example_args()),
        ["pred", "mindist"],
        1,
    ),
}


def build(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "version": MANIFEST_VERSION,
        "n_max": model.N_MAX,
        "m_max": model.M_MAX,
        "d": model.D,
        "jitter": model.JITTER,
        "graphs": {},
    }
    for name, (fn, args_fn, outputs, hyp_len) in GRAPHS.items():
        text = to_hlo_text(fn, args_fn())
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["graphs"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": ["x_obs", "y", "mask", "cands", "hyp"],
            "outputs": outputs,
            "hyp_len": hyp_len,
            "hlo_bytes": len(text),
        }
        print(f"wrote {path} ({len(text)} bytes)")
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath}")
    return manifest


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    # Kept for Makefile compatibility with single-file invocations.
    p.add_argument("--out", default=None, help=argparse.SUPPRESS)
    a = p.parse_args()
    out_dir = os.path.dirname(a.out) if a.out else a.out_dir
    build(out_dir or ".")


if __name__ == "__main__":
    main()
