"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes (including non-tile-multiples, which exercise the
wrappers' padding) and dtypes; fixed regression cases pin exact small
examples. This is the CORE correctness signal for the kernels embedded in
the AOT artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matern as k
from compile.kernels.ref import cubic_rbf_ref, matern52_ref, pairwise_sqdist_ref

jax.config.update("jax_enable_x64", True)


def rand(rng, n, d, dtype):
    return jnp.asarray(rng.standard_normal((n, d)), dtype=dtype)


dims = st.integers(min_value=1, max_value=40)
feat = st.integers(min_value=1, max_value=24)
dtypes = st.sampled_from([jnp.float32, jnp.float64])


def assert_close(got, want, dtype):
    """Scale-aware tolerance: the expanded-form sqdist cancels in f32."""
    got, want = np.asarray(got), np.asarray(want)
    scale = 1.0 + float(np.max(np.abs(want), initial=0.0))
    if dtype == jnp.float32:
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5 * scale)
    else:
        np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-12 * scale)


@settings(max_examples=8, deadline=None)
@given(n=dims, m=dims, d=feat, dtype=dtypes, seed=st.integers(0, 2**31 - 1))
def test_sqdist_matches_ref(n, m, d, dtype, seed):
    rng = np.random.default_rng(seed)
    a, b = rand(rng, n, d, dtype), rand(rng, m, d, dtype)
    got = k.pairwise_sqdist(a, b)
    want = pairwise_sqdist_ref(a, b)
    assert got.shape == (n, m)
    assert_close(got, want, dtype)


@settings(max_examples=8, deadline=None)
@given(
    n=dims,
    m=dims,
    d=feat,
    dtype=dtypes,
    ls=st.floats(0.05, 10.0),
    sv=st.floats(0.01, 10.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_matern52_matches_ref(n, m, d, dtype, ls, sv, seed):
    rng = np.random.default_rng(seed)
    a, b = rand(rng, n, d, dtype), rand(rng, m, d, dtype)
    got = k.matern52_gram(a, b, ls, sv)
    want = matern52_ref(a, b, ls, sv)
    assert_close(got, want, dtype)


@settings(max_examples=6, deadline=None)
@given(n=dims, m=dims, d=feat, seed=st.integers(0, 2**31 - 1))
def test_cubic_matches_ref(n, m, d, seed):
    rng = np.random.default_rng(seed)
    a, b = rand(rng, n, d, jnp.float64), rand(rng, m, d, jnp.float64)
    got = k.cubic_rbf_gram(a, b)
    want = cubic_rbf_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)


@settings(max_examples=6, deadline=None)
@given(n=dims, d=feat, ls=st.floats(0.1, 5.0), seed=st.integers(0, 2**31 - 1))
def test_matern_self_gram_properties(n, d, ls, seed):
    """Self-Gram: symmetric, diagonal == signal variance, PSD after jitter."""
    rng = np.random.default_rng(seed)
    a = rand(rng, n, d, jnp.float64)
    g = np.asarray(k.matern52_gram(a, a, ls, 2.0))
    np.testing.assert_allclose(g, g.T, rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(np.diag(g), 2.0, rtol=1e-7)
    np.linalg.cholesky(g + 1e-8 * np.eye(n))  # raises if not PSD


def test_sqdist_identical_points_zero():
    a = jnp.ones((5, 3), jnp.float32)
    np.testing.assert_allclose(k.pairwise_sqdist(a, a), 0.0, atol=1e-6)


def test_matern_exact_values():
    """Pin k(0) = sv and a hand-computed off-diagonal value."""
    a = jnp.array([[0.0], [1.0]], jnp.float64)
    g = np.asarray(k.matern52_gram(a, a, 1.0, 1.0))
    u = np.sqrt(5.0)
    want = (1.0 + u + u * u / 3.0) * np.exp(-u)
    np.testing.assert_allclose(g[0, 0], 1.0, rtol=1e-12)
    np.testing.assert_allclose(g[0, 1], want, rtol=1e-10)


def test_tile_multiple_shapes_unpadded():
    """Exactly tile-aligned shapes take the no-padding fast path."""
    rng = np.random.default_rng(0)
    a = rand(rng, 96, 20, jnp.float32)
    got = k.matern52_gram(a, a, 1.0, 1.0)
    want = matern52_ref(a, a, 1.0, 1.0)
    assert got.shape == (96, 96)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_dtype_promotion():
    rng = np.random.default_rng(1)
    a = rand(rng, 4, 3, jnp.float32)
    b = rand(rng, 5, 3, jnp.float64)
    assert k.pairwise_sqdist(a, b).dtype == jnp.float64


@pytest.mark.parametrize("d", [1, 20, 33])
def test_vmem_tile_budget(d):
    """Structural check: one grid step fits comfortably in TPU VMEM."""
    assert k.vmem_tile_bytes(d) < 16 * 1024 * 1024 / 64
