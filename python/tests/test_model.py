"""L2 correctness: the AOT surrogate graphs vs independent float64 oracles.

The key property proved here is *padding invariance*: the fixed-shape masked
graphs produce exactly the posterior / interpolant of the live rows, no
matter what garbage sits in the padded rows. This is what makes the AOT
contract (one compiled executable for all observation counts) sound.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model

jax.config.update("jax_enable_x64", True)

N, M, D = model.N_MAX, model.M_MAX, model.D


def pad_inputs(rng, n_live, garbage=0.0):
    """Random live rows + controllable garbage in the padded region."""
    x = np.zeros((N, D), np.float32)
    y = np.zeros((N,), np.float32)
    mask = np.zeros((N,), np.float32)
    x[:n_live] = rng.standard_normal((n_live, D))
    y[:n_live] = rng.standard_normal(n_live)
    mask[:n_live] = 1.0
    x[n_live:] = garbage
    c = rng.standard_normal((M, D)).astype(np.float32)
    cmask = np.ones((M,), np.float32)
    return x, y, mask, c, cmask


def gp_oracle(x, y, c, ls, sv, noise):
    """Plain float64 numpy GP posterior + lml (no masking, no padding)."""
    def matern(a, b):
        d2 = ((a[:, None, :] - b[None, :, :]) ** 2).sum(-1)
        u = np.sqrt(5.0 * d2) / ls
        return sv * (1 + u + u * u / 3) * np.exp(-u)

    kxx = matern(x, x) + (noise + model.JITTER) * np.eye(len(x))
    l = np.linalg.cholesky(kxx)
    alpha = np.linalg.solve(l.T, np.linalg.solve(l, y))
    kxc = matern(x, c)
    mean = kxc.T @ alpha
    v = np.linalg.solve(l, kxc)
    var = np.maximum(sv - (v * v).sum(0), 1e-12)
    lml = (
        -0.5 * y @ alpha
        - np.log(np.diag(l)).sum()
        - 0.5 * len(x) * np.log(2 * np.pi)
    )
    return mean, np.sqrt(var), lml


HYP = np.array([1.3, 2.0, 1e-2, 0.0, 2.0], np.float32)


@settings(max_examples=10, deadline=None)
@given(n_live=st.integers(2, 40), seed=st.integers(0, 2**31 - 1))
def test_gp_matches_float64_oracle(n_live, seed):
    rng = np.random.default_rng(seed)
    x, y, mask, c, cmask = pad_inputs(rng, n_live)
    hyp = HYP.copy()
    hyp[3] = float(y[:n_live].min())
    mean, std, ei, pi, neg_lcb, lml = model.gp_forward(x, y, mask, c, cmask, hyp)
    om, os_, olml = gp_oracle(
        x[:n_live].astype(np.float64),
        y[:n_live].astype(np.float64),
        c.astype(np.float64),
        hyp[0], hyp[1], hyp[2],
    )
    np.testing.assert_allclose(mean, om, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(std, os_, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(float(lml[0]), olml, rtol=2e-3, atol=2e-2)


def test_gp_padding_invariance():
    """Garbage in padded rows must not change any live output."""
    rng = np.random.default_rng(7)
    n_live = 17
    outs = []
    for garbage in (0.0, 123.0):
        rng2 = np.random.default_rng(7)
        x, y, mask, c, cmask = pad_inputs(rng2, n_live, garbage=garbage)
        y[n_live:] = 0.0  # contract: padded targets are zero
        outs.append(model.gp_forward(x, y, mask, c, cmask, HYP))
    for a, b in zip(*outs):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_gp_acquisition_formulas():
    """EI/PI/LCB recomputed from the returned mean/std must agree."""
    rng = np.random.default_rng(3)
    x, y, mask, c, cmask = pad_inputs(rng, 12)
    hyp = HYP.copy()
    hyp[3] = float(y.min())
    mean, std, ei, pi, neg_lcb, _ = model.gp_forward(x, y, mask, c, cmask, hyp)
    mean, std = np.asarray(mean, np.float64), np.asarray(std, np.float64)
    from scipy.stats import norm  # float64 oracle

    z = (hyp[3] - mean) / std
    np.testing.assert_allclose(ei, (hyp[3] - mean) * norm.cdf(z) + std * norm.pdf(z),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(pi, norm.cdf(z), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(neg_lcb, -(mean - hyp[4] * std), rtol=1e-4, atol=1e-4)


def test_gp_posterior_contracts_at_observed_points():
    """Posterior at an observed point: mean ~ y, std ~ sqrt(noise)-ish."""
    rng = np.random.default_rng(11)
    x, y, mask, c, cmask = pad_inputs(rng, 20)
    c[:20] = x[:20]  # candidates coincide with observations
    hyp = np.array([1.0, 1.0, 1e-6, 0.0, 2.0], np.float32)
    mean, std, *_ = model.gp_forward(x, y, mask, c, cmask, hyp)
    np.testing.assert_allclose(mean[:20], y[:20], atol=5e-3)
    assert float(jnp.max(std[:20])) < 0.05


def test_norm_cdf_accuracy():
    from scipy.stats import norm

    z = np.linspace(-6, 6, 241)
    got = model.norm_cdf(jnp.asarray(z))
    np.testing.assert_allclose(got, norm.cdf(z), atol=1e-6)


@settings(max_examples=8, deadline=None)
@given(n=st.integers(2, 40), seed=st.integers(0, 2**31 - 1))
def test_cholesky_scan_matches_numpy(n, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n))
    spd = a @ a.T + n * np.eye(n)
    l = model.cholesky_scan(jnp.asarray(spd))
    np.testing.assert_allclose(l, np.linalg.cholesky(spd), rtol=1e-8, atol=1e-8)


@settings(max_examples=8, deadline=None)
@given(n=st.integers(2, 30), m=st.integers(1, 5), seed=st.integers(0, 2**31 - 1))
def test_triangular_solves_match_numpy(n, m, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n))
    spd = a @ a.T + n * np.eye(n)
    l = np.linalg.cholesky(spd)
    b = rng.standard_normal((n, m))
    y = model.solve_lower(jnp.asarray(l), jnp.asarray(b))
    np.testing.assert_allclose(l @ np.asarray(y), b, rtol=1e-8, atol=1e-8)
    x = model.solve_upper_t(jnp.asarray(l), jnp.asarray(b))
    np.testing.assert_allclose(l.T @ np.asarray(x), b, rtol=1e-8, atol=1e-8)


def test_rbf_interpolates_training_targets():
    """With tiny ridge, the interpolant passes (close to) the data."""
    rng = np.random.default_rng(5)
    n_live = 15
    x, y, mask, c, cmask = pad_inputs(rng, n_live)
    c[:n_live] = x[:n_live]
    pred, mindist = model.rbf_forward(x, y, mask, c, cmask,
                                      np.array([1e-6], np.float32))
    np.testing.assert_allclose(pred[:n_live], y[:n_live], atol=5e-2)
    np.testing.assert_allclose(mindist[:n_live], 0.0, atol=1e-2)


def test_rbf_padding_invariance():
    rng = np.random.default_rng(9)
    outs = []
    for garbage in (0.0, 55.0):
        rng2 = np.random.default_rng(9)
        x, y, mask, c, cmask = pad_inputs(rng2, 10, garbage=garbage)
        y[10:] = 0.0
        outs.append(model.rbf_forward(x, y, mask, c, cmask,
                                      np.array([1e-4], np.float32)))
    for a, b in zip(*outs):
        np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3)


def test_rbf_mindist_matches_bruteforce():
    rng = np.random.default_rng(13)
    n_live = 8
    x, y, mask, c, cmask = pad_inputs(rng, n_live)
    _, mindist = model.rbf_forward(x, y, mask, c, cmask,
                                   np.array([1e-4], np.float32))
    want = np.sqrt(
        (((c[:, None, :] - x[None, :n_live, :]) ** 2).sum(-1)).min(1)
    )
    np.testing.assert_allclose(mindist, want, rtol=1e-3, atol=1e-3)
