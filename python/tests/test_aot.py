"""AOT lowering smoke tests: the artifacts the Rust runtime will load.

Checks that lowering is deterministic, emits plain HLO (no jaxlib LAPACK
custom-calls — the standalone XLA runtime cannot resolve them), and that
the entry signatures match the manifest contract consumed by
rust/src/runtime/artifacts.rs.
"""

import json

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def lowered():
    return {
        name: aot.to_hlo_text(fn, args_fn())
        for name, (fn, args_fn, _, _) in aot.GRAPHS.items()
    }


def test_lowering_emits_entry(lowered):
    for name, text in lowered.items():
        assert "ENTRY" in text, name
        assert len(text) > 1000, name


def test_no_custom_calls(lowered):
    """xla_extension 0.5.1 cannot resolve jaxlib custom-call targets."""
    for name, text in lowered.items():
        assert "custom-call" not in text, name


def test_entry_signatures(lowered):
    n, m, d = model.N_MAX, model.M_MAX, model.D
    gp = lowered["gp_matern52"]
    assert f"f32[{n},{d}]" in gp and f"f32[{m}]" in gp and "f32[5]" in gp
    rbf = lowered["rbf_cubic"]
    assert f"f32[{n},{d}]" in rbf and "f32[1]" in rbf


def test_lowering_deterministic():
    fn, args_fn, _, _ = aot.GRAPHS["gp_matern52"]
    assert aot.to_hlo_text(fn, args_fn()) == aot.to_hlo_text(fn, args_fn())


def test_build_manifest(tmp_path):
    manifest = aot.build(str(tmp_path))
    on_disk = json.loads((tmp_path / "manifest.json").read_text())
    assert on_disk == json.loads(json.dumps(manifest))
    assert on_disk["n_max"] == model.N_MAX
    assert on_disk["m_max"] == model.M_MAX
    assert on_disk["d"] == model.D
    for name, g in on_disk["graphs"].items():
        assert (tmp_path / g["file"]).stat().st_size == g["hlo_bytes"]
        assert g["inputs"] == ["x_obs", "y", "mask", "cands", "hyp"]
